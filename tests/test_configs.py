"""Assigned architecture configs must match the public-literature numbers."""
from __future__ import annotations

import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config

# (arch, layers, d_model, heads, kv, d_ff, vocab, experts, top_k)
ASSIGNED = {
    "command_r_35b": (40, 8192, 64, 8, 22528, 256000, 0, 0),
    "minitron_4b": (32, 3072, 24, 8, 9216, 256000, 0, 0),
    "mistral_nemo_12b": (40, 5120, 32, 8, 14336, 131072, 0, 0),
    "olmo_1b": (16, 2048, 16, 16, 8192, 50304, 0, 0),
    "llama32_vision_11b": (40, 4096, 32, 8, 14336, 128256, 0, 0),
    "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304, 64, 8),
    "qwen3_moe_235b": (94, 4096, 64, 4, 1536, 151936, 128, 8),
    "jamba_v01_52b": (32, 4096, 32, 8, 14336, 65536, 16, 2),
    "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206, 0, 0),
    "mamba2_130m": (24, 768, 0, 0, 0, 50280, 0, 0),
}


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_exact_config_numbers(arch):
    cfg = get_config(arch)
    nl, d, h, kv, ff, v, e, k = ASSIGNED[arch]
    assert cfg.n_layers == nl
    assert cfg.d_model == d
    assert cfg.vocab == v
    assert cfg.moe_experts == e
    assert cfg.moe_top_k == k
    if h:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff


def test_family_flags():
    assert get_config("mamba2_130m").family == "ssm"
    assert get_config("mamba2_130m").attention_free
    assert get_config("jamba_v01_52b").family == "hybrid"
    assert get_config("llama32_vision_11b").family == "vlm"
    assert get_config("llama32_vision_11b").cross_attn_every > 0
    assert get_config("seamless_m4t_medium").is_enc_dec
    assert get_config("olmo_1b").norm == "nonparam_ln"
    assert get_config("qwen3_moe_235b").family == "moe"


def test_jamba_interleave():
    """Jamba: mamba:attention 1:7 interleave (one attn layer per 8), MoE on
    alternating layers (16e top-2)."""
    cfg = get_config("jamba_v01_52b")
    kinds = [cfg.layer_kind(i) for i in range(cfg.block_size)]
    assert kinds.count("attn") == 1
    assert kinds.count("ssm") == cfg.block_size - 1
    moes = [cfg.layer_is_moe(i) for i in range(cfg.block_size)]
    assert sum(moes) == cfg.block_size // 2


def test_shape_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_cells_only_for_subquadratic():
    for arch in ASSIGNED:
        names = cells(arch)
        if arch in ("mamba2_130m", "jamba_v01_52b"):
            assert "long_500k" in names, arch
        else:
            assert "long_500k" not in names, arch


def test_block_pattern_divides_layers():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.n_layers % cfg.block_size == 0
        assert cfg.n_blocks * cfg.block_size == cfg.n_layers


def test_smoke_configs_same_family():
    for arch in ARCH_IDS:
        full, smoke = get_config(arch), get_config(arch, smoke=True)
        assert smoke.family == full.family
        assert smoke.norm == full.norm
        assert bool(smoke.moe_experts) == bool(full.moe_experts)
        assert smoke.n_layers <= 4
        assert smoke.d_model <= 256

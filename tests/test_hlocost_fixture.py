"""hlocost against a committed canned HLO module — exact, no jax.

`tests/data/canned_decode.hlo` is hand-written to exercise every pricing
path with hand-computable answers: trip-count-scaled while bodies (one
nested pair — multipliers must compound), fusion boundary pricing (body
FLOPs through `calls=`, bytes at the boundary only, memoized across the
second fusion of the same body), dot contracting-dim FLOPs, and all five
collective kinds under both `replica_groups` spellings. Every assert below
is an exact arithmetic identity derived next to it — if the parser or the
cost model drifts, the number names the broken path.
"""
from __future__ import annotations

import pathlib

from repro.launch import hlocost

FIXTURE = pathlib.Path(__file__).parent / "data" / "canned_decode.hlo"

# hand-derived constants of the canned module ---------------------------------
DOT_FLOPS = 2 * 64 * 64 * 64          # out 64x64, contracted dim 64
FUSION_FLOPS = 32 * 32 + 32 * 32      # multiply + add over bf16[32,32]
AR_PAYLOAD = 64 * 64 * 4              # f32[64,64] all-reduce operand
RES_PAYLOAD = 64 * 64 * 4             # f32[64,64] entry-level operands
AG_PAYLOAD = 32 * 32 * 2              # bf16[32,32] all-gather operand


def _summary() -> hlocost.CostSummary:
    return hlocost.analyze(FIXTURE.read_text())


def test_trip_counts_recorded_in_walk_order():
    s = _summary()
    assert s.while_trip_counts == [5, 4, 3]


def test_flops_exact_with_nested_trip_scaling():
    s = _summary()
    want = (
        DOT_FLOPS * 5            # dot in the 5-trip loop body
        + 1 * 5                  # scalar add in that body
        + 1 * 4                  # scalar add in the 4-trip outer body
        + 1 * (4 * 3)            # scalar add in the nested 3-trip body
        + 16 * (4 * 3)           # f32[16] multiply in the nested body
        + FUSION_FLOPS * 2       # two fusions of the same body (memo path)
    )
    assert s.flops == want


def test_fusion_priced_at_boundary_only():
    """Fusion bytes are operand+result at the call site; the interior
    multiply/add tensors are fused away and must not be charged."""
    s = _summary()
    boundary = 32 * 32 * 2 + 32 * 32 * 2       # bf16 operand + bf16 result
    assert s.bytes_by_opcode["fusion"] == boundary * 2


def test_dot_bytes_scaled_by_trips():
    s = _summary()
    per_trip = 3 * 64 * 64 * 4                 # two operands + result, f32
    assert s.bytes_by_opcode["dot"] == per_trip * 5


def test_collective_link_bytes_per_kind_exact():
    """Ring-algorithm link terms: AG s·(S-1), AR 2n(S-1)/S, RS/A2A
    n(S-1)/S, permute n — with the all-reduce inside the 5-trip loop."""
    s = _summary()
    assert s.collective_bytes == {
        "all-reduce": 2.0 * AR_PAYLOAD * (4 - 1) / 4 * 5,
        "all-gather": AG_PAYLOAD * (4 - 1),
        "reduce-scatter": RES_PAYLOAD * (2 - 1) / 2,
        "all-to-all": RES_PAYLOAD * (8 - 1) / 8,
        "collective-permute": RES_PAYLOAD,      # participants=1 special case
    }
    assert s.link_traffic_bytes == sum(s.collective_bytes.values())


def test_participants_from_both_replica_group_spellings():
    s = _summary()
    by_kind = {r.kind: r for r in s.collectives}
    assert by_kind["all-gather"].participants == 4      # [2,4]<= iota form
    assert by_kind["all-reduce"].participants == 4      # {{0,1,2,3}} list
    assert by_kind["reduce-scatter"].participants == 2
    assert by_kind["all-to-all"].participants == 8
    assert by_kind["all-reduce"].trips == 5
    assert len(s.collectives) == 5


def test_total_bytes_accessed_exact():
    s = _summary()
    want = (
        3 * 64 * 64 * 4 * 5                    # dot: 2 operands + result, x5
        + AR_PAYLOAD * 5                       # all-reduce payload, x5
        + 12 * 5 + 12 * 4 + 12 * 12            # the three scalar adds
        + (3 * 16 * 4) * 12                    # nested f32[16] multiply
        + 9 * 5 + 9 * 4 + 9 * 12               # the three loop compares
        + (32 * 32 * 2 * 2) * 2                # two fusion boundaries
        + AG_PAYLOAD + RES_PAYLOAD * 3         # entry collective payloads
    )
    assert s.bytes_accessed == want


def test_trip_count_rescale_shifts_only_loop_costs():
    """Doubling one loop's annotated trip count must add exactly that
    loop's per-trip cost — nothing outside the loop may move."""
    text = FIXTURE.read_text()
    base = hlocost.analyze(text)
    bumped = hlocost.analyze(text.replace('{"n":"5"}', '{"n":"6"}'))
    assert bumped.flops - base.flops == DOT_FLOPS + 1
    assert bumped.while_trip_counts == [6, 4, 3]
    assert (bumped.collective_bytes["all-reduce"]
            - base.collective_bytes["all-reduce"]
            ) == 2.0 * AR_PAYLOAD * (4 - 1) / 4
    for kind in ("all-gather", "reduce-scatter", "all-to-all",
                 "collective-permute"):
        assert bumped.collective_bytes[kind] == base.collective_bytes[kind]


def test_collective_schedule_sorted_by_link_traffic():
    sched = hlocost.collective_schedule(_summary())
    assert sched[0]["kind"] == "all-reduce"    # 122880 link bytes dominates
    totals = [row["total_link_bytes"] for row in sched]
    assert totals == sorted(totals, reverse=True)

"""Inter-chip optimization pass tests (paper §IV), including the
columnar-candidate certification: the batched lexicographic argmin over
the priced PlanMatrix must pick the same winner as the scalar enumeration
scan, bit for bit, including infeasible-tie ordering."""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import pytest

from repro.core.interchip import (TrainWorkload, candidate_matrix,
                                  candidate_plans, evaluate_plan,
                                  optimize_inter_chip, select_plan,
                                  select_plans, winner_rows,
                                  _subdivide_dims)
from repro.core.memo import clear_caches
from repro.systems.chips import HBM, ICI, NVLINK, TPU_V4, H100
from repro.systems.system import SystemSpec
from repro.systems.topology import ring, torus2d
from repro.workloads.llm import LLMShape, gpt_workload

SMALL = LLMShape("small", n_layers=8, d_model=1024, n_heads=8, n_kv_heads=8,
                 d_ff=4096, vocab=32000, seq=2048)


def _system(n=16, chip=TPU_V4, topo=None):
    return SystemSpec("sys", chip, HBM, topo or torus2d(n, ICI))


def test_optimizer_returns_feasible_best():
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    plan = optimize_inter_chip(work, sys_)
    assert plan.tp * plan.pp * plan.dp == 16
    assert 0.0 < plan.utilization <= 1.0
    assert plan.feasible
    assert plan.iter_time > 0


def test_fixed_combo_matches_manual_evaluate():
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    plan = optimize_inter_chip(work, sys_, fixed=(4, 2, 2))
    assert (plan.tp, plan.pp, plan.dp) == (4, 2, 2)
    cands = _subdivide_dims(sys_.topology, (4, 2, 2), True)
    manual = [evaluate_plan(work, sys_, 4, 2, 2, *c) for c in cands]
    manual = [m for m in manual if m is not None]
    assert plan.iter_time == pytest.approx(
        min(m.iter_time for m in manual), rel=1e-9)


def test_optimum_beats_every_fixed_point():
    work = gpt_workload(SMALL, global_batch=32, microbatch=1)
    sys_ = _system(8, topo=ring(8, ICI))
    best = optimize_inter_chip(work, sys_)
    for combo in [(8, 1, 1), (4, 2, 1), (2, 2, 2), (1, 1, 8)]:
        try:
            p = optimize_inter_chip(work, sys_, fixed=combo)
        except ValueError:
            continue
        if p.feasible:
            assert best.iter_time <= p.iter_time * (1 + 1e-9)


def test_tp_comm_grows_with_degree():
    """More TP ⇒ more collective seconds per layer (same payload, more chips
    in the group, and fewer FLOPs to hide it)."""
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    t2 = optimize_inter_chip(work, sys_, fixed=(2, 1, 8))
    t8 = optimize_inter_chip(work, sys_, fixed=(8, 1, 2))
    assert t8.breakdown["tp_comm"] > 0
    comm_frac2 = t2.breakdown["tp_comm"] / t2.iter_time
    comm_frac8 = t8.breakdown["tp_comm"] / t8.iter_time
    assert comm_frac8 > comm_frac2


def test_pipeline_bubble_fraction():
    """bubble/(useful+bubble) = (pp-1)/(n_micro+pp-1) in the 1F1B model."""
    work = gpt_workload(SMALL, global_batch=32, microbatch=1)
    sys_ = _system(8, topo=ring(8, ICI))
    plan = optimize_inter_chip(work, sys_, fixed=(1, 4, 2))
    n_micro = plan.n_micro
    assert n_micro == 32 // 2
    frac = plan.breakdown["bubble"] / (
        plan.breakdown["bubble"]
        + n_micro * (plan.t_stage_fwd + plan.breakdown["bwd"] / n_micro))
    assert frac == pytest.approx((4 - 1) / (n_micro + 4 - 1), rel=0.35)


def test_memory_infeasibility_flagged():
    big = LLMShape("big", n_layers=96, d_model=12288, n_heads=96,
                   n_kv_heads=96, d_ff=4 * 12288, vocab=50257, seq=2048)
    work = gpt_workload(big, global_batch=8, microbatch=1)
    tiny_mem = dataclasses.replace(HBM, capacity=1e9)  # 1 GB per chip
    sys_ = SystemSpec("sys", H100, tiny_mem, ring(8, ICI))
    plan = optimize_inter_chip(work, sys_, fixed=(8, 1, 1))
    assert not plan.feasible


def test_subdivide_dims_respects_paper_restriction():
    """With allow_subdivision=False a 16-ring cannot split into 4×4."""
    topo = ring(16, ICI)
    strict = _subdivide_dims(topo, (4, 4, 1), allow_subdivision=False)
    relaxed = _subdivide_dims(topo, (4, 4, 1), allow_subdivision=True)
    assert strict == []
    assert relaxed, "subdivision must make 4x4 feasible on a 16-ring"
    t2 = torus2d(16, ICI)
    strict2 = _subdivide_dims(t2, (4, 4, 1), allow_subdivision=False)
    assert strict2  # 4x4 maps directly onto the 4x4 torus dims


def test_dp_allreduce_charged_once_per_iteration():
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    p = optimize_inter_chip(work, sys_, fixed=(1, 1, 16))
    w_chip = work.total_weight_bytes()
    expect = sys_.topology.all_reduce(w_chip, [0, 1])
    assert p.breakdown["dp_comm"] == pytest.approx(expect, rel=0.5)


# ---------------------- columnar candidate selection -------------------------
def _scalar_winner(plans, capacity):
    """Literal transcription of the serial first-strictly-smaller scan,
    returning the winning *index* (the tie-ordering ground truth)."""
    bkey, bi = None, -1
    for i, p in enumerate(plans):
        key = (p.per_chip_mem_bytes > capacity, p.iter_time)
        if bkey is None or key < bkey:
            bkey, bi = key, i
    return bi, (not bkey[0]) if bkey is not None else None


def _random_workload(rng):
    shape = LLMShape("rand", n_layers=int(rng.integers(2, 10)),
                     d_model=int(rng.choice([256, 512, 1024])),
                     n_heads=8, n_kv_heads=int(rng.choice([4, 8])),
                     d_ff=int(rng.choice([1024, 2048])), vocab=8000,
                     seq=int(rng.choice([512, 1024])))
    return gpt_workload(shape, global_batch=int(rng.choice([16, 32, 64])),
                        microbatch=1)


def test_columnar_select_matches_scalar_enumeration_seeded():
    """The acceptance property for the columnar path: across seeded random
    workloads and systems, select_plan over the candidate matrix picks the
    same candidate *index* as the scalar scan for every capacity regime —
    all-feasible, none-feasible (pure iter_time ties), and boundary
    capacities sitting exactly on a candidate's memory footprint."""
    rng = np.random.default_rng(42)
    checked_caps = 0
    for _ in range(10):
        clear_caches()
        work = _random_workload(rng)
        n = int(rng.choice([8, 16]))
        topo = ring(n, ICI) if rng.integers(2) else torus2d(n, ICI)
        chip = TPU_V4 if rng.integers(2) else H100
        sys_ = SystemSpec("sys", chip, HBM, topo)
        plans = candidate_plans(work, sys_, max_tp=16)
        cands = candidate_matrix(work, sys_, max_tp=16)
        assert len(cands) == len(plans) > 0
        priced = cands.priced("numpy")
        # the candidate vectors re-derive iter_time/mem through the batched
        # formula — they must reproduce the plans' own scalar fields bitwise
        want_it = np.array([p.iter_time for p in plans])
        want_mem = np.array([p.per_chip_mem_bytes for p in plans])
        assert (priced["iter_time"].view(np.uint64)
                == want_it.view(np.uint64)).all()
        assert (priced["per_chip_mem_bytes"].view(np.uint64)
                == want_mem.view(np.uint64)).all()
        mems = sorted({p.per_chip_mem_bytes for p in plans})
        caps = [0.0, math.inf, mems[0], mems[len(mems) // 2],
                float(rng.uniform(mems[0], mems[-1]))]
        rows = winner_rows(priced["iter_time"],
                           priced["per_chip_mem_bytes"], caps)
        for cap, row in zip(caps, rows):
            bi, feasible = _scalar_winner(plans, cap)
            assert row == bi, f"cap={cap}: columnar {row} != scalar {bi}"
            got = select_plan(cands, cap)
            ref = select_plan(plans, cap)
            assert got.feasible == ref.feasible == feasible
            assert (got.tp, got.pp, got.dp) == (ref.tp, ref.pp, ref.dp)
            assert got.iter_time == ref.iter_time
            assert got.per_chip_mem_bytes == ref.per_chip_mem_bytes
            checked_caps += 1
    assert checked_caps >= 50


def test_infeasible_tie_ordering_prefers_first_candidate():
    """With capacity 0 every candidate is infeasible; symmetric dim
    assignments produce exact iter_time ties, and the argmin must resolve
    them to the lowest enumeration index — the serial acceptance order."""
    clear_caches()
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    plans = candidate_plans(work, sys_, max_tp=16)
    cands = candidate_matrix(work, sys_, max_tp=16)
    it = np.array([p.iter_time for p in plans])
    assert len(it) > len(np.unique(it)), "grid should produce exact ties"
    row = winner_rows(cands.priced()["iter_time"],
                      cands.priced()["per_chip_mem_bytes"], [0.0])[0]
    first_min = int(np.flatnonzero(it == it.min())[0])
    assert row == _scalar_winner(plans, 0.0)[0] == first_min
    assert not select_plan(cands, 0.0).feasible


def test_select_plans_batches_all_capacities_identically():
    clear_caches()
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    cands = candidate_matrix(work, sys_, max_tp=16)
    mems = sorted(p.per_chip_mem_bytes for p in cands.plans)
    caps = [0.0, mems[0], mems[-1] * 2.0]
    batch = select_plans(cands, caps)
    for cap, got in zip(caps, batch):
        one = select_plan(cands, cap)
        assert (got.tp, got.pp, got.dp, got.feasible, got.iter_time) == \
            (one.tp, one.pp, one.dp, one.feasible, one.iter_time)


def test_select_plan_empty_candidates_returns_none():
    from repro.core.interchip import CandidateSet
    from repro.core.pricing import PlanMatrix

    empty = CandidateSet(plans=[], matrix=PlanMatrix.concat([]))
    assert select_plan(empty, 1e12) is None
    assert select_plans(empty, [1e12, 0.0]) == [None, None]
    assert select_plan([], 1e12) is None


def test_candidate_matrix_tags_match_plan_coordinates():
    clear_caches()
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    cands = candidate_matrix(work, sys_, max_tp=16)
    assert cands.matrix.tags.shape == (len(cands), 4)
    for (tp, pp, dp, a), plan in zip(cands.matrix.tags.tolist(),
                                     cands.plans):
        assert (tp, pp, dp) == (plan.tp, plan.pp, plan.dp)
        assert a >= 0


def test_nvlink_never_slower_than_pcie():
    from repro.systems.chips import PCIE
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    fast = SystemSpec("f", TPU_V4, HBM, torus2d(16, NVLINK))
    slow = SystemSpec("s", TPU_V4, HBM, torus2d(16, PCIE))
    pf = optimize_inter_chip(work, fast)
    ps = optimize_inter_chip(work, slow)
    assert pf.iter_time <= ps.iter_time * (1 + 1e-9)


# --------------------------- candidate pruning -------------------------------
def test_pruned_select_matches_unpruned_seeded():
    """The pruning acceptance property: across seeded random workloads,
    systems and capacity regimes (all-feasible, none-feasible ties,
    boundary capacities), select_plans with the pruning stage picks
    plans identical to the unpruned columnar path and the scalar scan —
    while pricing strictly fewer candidate rows overall."""
    from repro.core.interchip import select_candidates

    rng = np.random.default_rng(1234)
    enumerated = survived = 0
    for _ in range(8):
        clear_caches()
        work = _random_workload(rng)
        n = int(rng.choice([8, 16]))
        topo = ring(n, ICI) if rng.integers(2) else torus2d(n, ICI)
        chip = TPU_V4 if rng.integers(2) else H100
        sys_ = SystemSpec("sys", chip, HBM, topo)
        plans = candidate_plans(work, sys_, max_tp=16)
        cands = candidate_matrix(work, sys_, max_tp=16)
        mems = sorted({p.per_chip_mem_bytes for p in plans})
        caps = [0.0, math.inf, mems[0], mems[len(mems) // 2],
                float(rng.uniform(mems[0], mems[-1])), HBM.capacity]
        sel = select_candidates(cands, caps, prune="on")
        ref = select_candidates(cands, caps, prune="off")
        assert sel.rows == ref.rows
        for cap, row in zip(caps, sel.rows):
            assert row == _scalar_winner(plans, cap)[0]
        on = select_plans(cands, caps, prune="on")
        off = select_plans(cands, caps, prune="off")
        for a, b in zip(on, off):
            assert (a.tp, a.pp, a.dp, a.feasible) == \
                (b.tp, b.pp, b.dp, b.feasible)
            assert a.iter_time == b.iter_time
            assert a.per_chip_mem_bytes == b.per_chip_mem_bytes
        assert sel.stats["survived"] <= sel.stats["enumerated"]
        assert (sel.stats["mem_pruned"] + sel.stats["dominance_pruned"]
                + sel.stats["survived"]
                >= sel.stats["enumerated"])  # masks may overlap
        enumerated += sel.stats["enumerated"]
        survived += sel.stats["survived"]
    assert survived < enumerated, "pruning never dropped a single row"


def test_pruned_infeasible_tie_ordering_prefers_first_candidate():
    """Capacity 0 makes every candidate infeasible: the pruned path must
    reproduce the fallback winner — the FIRST row of globally minimal
    iter_time — while pricing only the (tiny) surviving set."""
    from repro.core.interchip import select_candidates

    clear_caches()
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    plans = candidate_plans(work, sys_, max_tp=16)
    cands = candidate_matrix(work, sys_, max_tp=16)
    it = np.array([p.iter_time for p in plans])
    assert len(it) > len(np.unique(it)), "grid should produce exact ties"
    sel = select_candidates(cands, [0.0], prune="on")
    first_min = int(np.flatnonzero(it == it.min())[0])
    assert sel.rows == [first_min] == [_scalar_winner(plans, 0.0)[0]]
    assert sel.stats["survived"] < sel.stats["enumerated"]
    assert not select_plan(cands, 0.0, prune="on").feasible


def test_prune_matrix_bounds_and_survivor_map():
    """Structural contracts of the pruned view: iter_lb a true lower
    bound on iter_time, survivors ascending and consistent with the
    compacted matrix, stats that add up."""
    from repro.core.interchip import prune_matrix
    from repro.core.pricing import selection_columns

    clear_caches()
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    cands = candidate_matrix(work, _system(16), max_tp=16)
    sel = selection_columns(cands.matrix.cols)
    assert (sel["iter_lb"] <= sel["iter_time"]).all()
    priced = cands.priced("numpy")
    assert (sel["iter_time"].view(np.uint64)
            == priced["iter_time"].view(np.uint64)).all()
    assert (sel["per_chip_mem_bytes"].view(np.uint64)
            == priced["per_chip_mem_bytes"].view(np.uint64)).all()
    pc = cands.pruned(HBM.capacity)
    assert (np.diff(pc.survivors) > 0).all()
    assert len(pc.matrix) == len(pc.survivors) == pc.stats["survived"]
    for local, orig in enumerate(pc.survivors.tolist()):
        assert (pc.matrix.tags[local] == cands.matrix.tags[orig]).all()
    got = pc.priced("numpy")["iter_time"]
    assert (got.view(np.uint64)
            == priced["iter_time"][pc.survivors].view(np.uint64)).all()


def test_prune_policy_resolution_and_env(monkeypatch):
    from repro.core.interchip import PRUNE_ENV_VAR, default_prune, resolve_prune

    assert resolve_prune(True) and not resolve_prune(False)
    assert resolve_prune("on") and not resolve_prune("off")
    monkeypatch.delenv(PRUNE_ENV_VAR, raising=False)
    assert default_prune() == "on" and resolve_prune("auto")
    monkeypatch.setenv(PRUNE_ENV_VAR, "off")
    assert default_prune() == "off" and not resolve_prune("auto")
    # boolean-ish spellings are honored ("false" used to silently mean on)
    monkeypatch.setenv(PRUNE_ENV_VAR, "false")
    assert default_prune() == "off" and not resolve_prune("auto")
    monkeypatch.setenv(PRUNE_ENV_VAR, "1")
    assert default_prune() == "on" and resolve_prune("auto")
    # unknown spellings raise instead of silently enabling
    monkeypatch.setenv(PRUNE_ENV_VAR, "gibberish")
    with pytest.raises(ValueError, match=PRUNE_ENV_VAR):
        default_prune()
    with pytest.raises(ValueError):
        resolve_prune("sometimes")


def test_optimize_inter_chip_pruned_matches_reference():
    clear_caches()
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    ref = optimize_inter_chip(work, sys_)               # prune="off" default
    got = optimize_inter_chip(work, sys_, prune="on")
    assert (got.tp, got.pp, got.dp, got.feasible) == \
        (ref.tp, ref.pp, ref.dp, ref.feasible)
    assert got.iter_time == ref.iter_time


def _synthetic_matrix(vectors):
    from repro.core.pricing import PlanMatrix

    return PlanMatrix.from_vectors(vectors,
                                   [(1, 1, 1, i) for i in range(len(vectors))])


def test_prune_matrix_synthetic_with_duplicates_and_ties_seeded():
    """Synthetic candidate batches with injected duplicate rows (exact
    iter_time AND mem ties): the pruned argmin must still resolve to the
    first-index winner of the scalar scan for every capacity."""
    from repro.core.interchip import prune_matrix, winner_rows as wr
    from repro.core.pricing import price_plans, random_plan_vectors

    rng = np.random.default_rng(77)
    for trial in range(20):
        base = random_plan_vectors(int(rng.integers(2, 40)),
                                   seed=int(rng.integers(0, 10_000)))
        # duplicate a random prefix to force exact ties at distinct rows
        vectors = base + base[:int(rng.integers(1, len(base) + 1))]
        m = _synthetic_matrix(vectors)
        priced = price_plans(m.cols, backend="numpy")
        it, mem = priced["iter_time"], priced["per_chip_mem_bytes"]
        caps = [0.0, float(np.inf), float(np.median(mem)),
                float(mem.min()), float(mem.max()),
                float(rng.uniform(mem.min(), mem.max()))]
        want = wr(it, mem, caps)
        pc = prune_matrix(m, max(caps))
        pp = price_plans(pc.matrix.cols, backend="numpy")
        local = wr(pp["iter_time"], pp["per_chip_mem_bytes"], caps)
        got = [int(pc.survivors[r]) for r in local]
        assert got == want, f"trial {trial}: {got} != {want}"


# ------------------------ hypothesis variant (dev extra) ---------------------
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=120, deadline=None)
    @given(seed=st.integers(0, 2**20), n=st.integers(1, 60),
           dup=st.integers(0, 60),
           cap_kind=st.sampled_from(["zero", "inf", "min", "max", "mid"]),
           extra_cap=st.floats(0.0, 1e13, allow_nan=False))
    def test_pruned_winner_identity_hypothesis(seed, n, dup, cap_kind,
                                               extra_cap):
        """Property form of the pruning acceptance criterion: for ANY
        candidate batch (random plan vectors, duplicates forcing exact
        iter/mem ties at distinct rows) and ANY capacity — including the
        all-infeasible fallback regime — pruned and unpruned selection
        return the same original-row winner."""
        from repro.core.interchip import prune_matrix, winner_rows as wr
        from repro.core.pricing import price_plans, random_plan_vectors

        base = random_plan_vectors(n, seed=seed)
        vectors = base + base[:min(dup, n)]
        m = _synthetic_matrix(vectors)
        priced = price_plans(m.cols, backend="numpy")
        it, mem = priced["iter_time"], priced["per_chip_mem_bytes"]
        cap = {"zero": 0.0, "inf": float(np.inf), "min": float(mem.min()),
               "max": float(mem.max()),
               "mid": float(np.median(mem))}[cap_kind]
        caps = [cap, extra_cap]
        want = wr(it, mem, caps)
        pc = prune_matrix(m, max(caps))
        pp = price_plans(pc.matrix.cols, backend="numpy")
        local = wr(pp["iter_time"], pp["per_chip_mem_bytes"], caps)
        assert [int(pc.survivors[r]) for r in local] == want

"""Inter-chip optimization pass tests (paper §IV)."""
from __future__ import annotations

import dataclasses

import pytest

from repro.core.interchip import (TrainWorkload, evaluate_plan,
                                  optimize_inter_chip, _subdivide_dims)
from repro.systems.chips import HBM, ICI, NVLINK, TPU_V4, H100
from repro.systems.system import SystemSpec
from repro.systems.topology import ring, torus2d
from repro.workloads.llm import LLMShape, gpt_workload

SMALL = LLMShape("small", n_layers=8, d_model=1024, n_heads=8, n_kv_heads=8,
                 d_ff=4096, vocab=32000, seq=2048)


def _system(n=16, chip=TPU_V4, topo=None):
    return SystemSpec("sys", chip, HBM, topo or torus2d(n, ICI))


def test_optimizer_returns_feasible_best():
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    plan = optimize_inter_chip(work, sys_)
    assert plan.tp * plan.pp * plan.dp == 16
    assert 0.0 < plan.utilization <= 1.0
    assert plan.feasible
    assert plan.iter_time > 0


def test_fixed_combo_matches_manual_evaluate():
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    plan = optimize_inter_chip(work, sys_, fixed=(4, 2, 2))
    assert (plan.tp, plan.pp, plan.dp) == (4, 2, 2)
    cands = _subdivide_dims(sys_.topology, (4, 2, 2), True)
    manual = [evaluate_plan(work, sys_, 4, 2, 2, *c) for c in cands]
    manual = [m for m in manual if m is not None]
    assert plan.iter_time == pytest.approx(
        min(m.iter_time for m in manual), rel=1e-9)


def test_optimum_beats_every_fixed_point():
    work = gpt_workload(SMALL, global_batch=32, microbatch=1)
    sys_ = _system(8, topo=ring(8, ICI))
    best = optimize_inter_chip(work, sys_)
    for combo in [(8, 1, 1), (4, 2, 1), (2, 2, 2), (1, 1, 8)]:
        try:
            p = optimize_inter_chip(work, sys_, fixed=combo)
        except ValueError:
            continue
        if p.feasible:
            assert best.iter_time <= p.iter_time * (1 + 1e-9)


def test_tp_comm_grows_with_degree():
    """More TP ⇒ more collective seconds per layer (same payload, more chips
    in the group, and fewer FLOPs to hide it)."""
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    t2 = optimize_inter_chip(work, sys_, fixed=(2, 1, 8))
    t8 = optimize_inter_chip(work, sys_, fixed=(8, 1, 2))
    assert t8.breakdown["tp_comm"] > 0
    comm_frac2 = t2.breakdown["tp_comm"] / t2.iter_time
    comm_frac8 = t8.breakdown["tp_comm"] / t8.iter_time
    assert comm_frac8 > comm_frac2


def test_pipeline_bubble_fraction():
    """bubble/(useful+bubble) = (pp-1)/(n_micro+pp-1) in the 1F1B model."""
    work = gpt_workload(SMALL, global_batch=32, microbatch=1)
    sys_ = _system(8, topo=ring(8, ICI))
    plan = optimize_inter_chip(work, sys_, fixed=(1, 4, 2))
    n_micro = plan.n_micro
    assert n_micro == 32 // 2
    frac = plan.breakdown["bubble"] / (
        plan.breakdown["bubble"]
        + n_micro * (plan.t_stage_fwd + plan.breakdown["bwd"] / n_micro))
    assert frac == pytest.approx((4 - 1) / (n_micro + 4 - 1), rel=0.35)


def test_memory_infeasibility_flagged():
    big = LLMShape("big", n_layers=96, d_model=12288, n_heads=96,
                   n_kv_heads=96, d_ff=4 * 12288, vocab=50257, seq=2048)
    work = gpt_workload(big, global_batch=8, microbatch=1)
    tiny_mem = dataclasses.replace(HBM, capacity=1e9)  # 1 GB per chip
    sys_ = SystemSpec("sys", H100, tiny_mem, ring(8, ICI))
    plan = optimize_inter_chip(work, sys_, fixed=(8, 1, 1))
    assert not plan.feasible


def test_subdivide_dims_respects_paper_restriction():
    """With allow_subdivision=False a 16-ring cannot split into 4×4."""
    topo = ring(16, ICI)
    strict = _subdivide_dims(topo, (4, 4, 1), allow_subdivision=False)
    relaxed = _subdivide_dims(topo, (4, 4, 1), allow_subdivision=True)
    assert strict == []
    assert relaxed, "subdivision must make 4x4 feasible on a 16-ring"
    t2 = torus2d(16, ICI)
    strict2 = _subdivide_dims(t2, (4, 4, 1), allow_subdivision=False)
    assert strict2  # 4x4 maps directly onto the 4x4 torus dims


def test_dp_allreduce_charged_once_per_iteration():
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    sys_ = _system(16)
    p = optimize_inter_chip(work, sys_, fixed=(1, 1, 16))
    w_chip = work.total_weight_bytes()
    expect = sys_.topology.all_reduce(w_chip, [0, 1])
    assert p.breakdown["dp_comm"] == pytest.approx(expect, rel=0.5)


def test_nvlink_never_slower_than_pcie():
    from repro.systems.chips import PCIE
    work = gpt_workload(SMALL, global_batch=64, microbatch=1)
    fast = SystemSpec("f", TPU_V4, HBM, torus2d(16, NVLINK))
    slow = SystemSpec("s", TPU_V4, HBM, torus2d(16, PCIE))
    pf = optimize_inter_chip(work, fast)
    ps = optimize_inter_chip(work, slow)
    assert pf.iter_time <= ps.iter_time * (1 + 1e-9)

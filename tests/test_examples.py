"""The shipped examples must run end-to-end (fast configurations)."""
from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=600, extra_env=None):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu", **(extra_env or {}))
    proc = subprocess.run([sys.executable, *args], env=env, cwd=REPO,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"OUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_quickstart_example():
    out = _run(["examples/quickstart.py"])
    assert "DFModel dataflow" in out and "speedup" in out


def test_train_e2e_example():
    out = _run(["examples/train_e2e.py", "--steps", "12", "--batch", "2",
                "--seq", "64"])
    assert "done;" in out


def test_serve_batched_example():
    out = _run(["examples/serve_batched.py", "--tokens", "4",
                "--batch", "2"])
    assert "TPOT" in out


def test_dse_scenario_example():
    out = _run(["examples/dse_scenario.py"])
    assert "best throughput utilization" in out


def test_serve_dse_example():
    out = _run(["examples/serve_dse.py"])
    assert "dedup hits" in out
    assert "zero new solves" in out
    assert "certified=True" in out
    assert "serve_dse: OK" in out


def test_launch_train_module():
    out = _run(["-m", "repro.launch.train", "--arch", "olmo_1b", "--smoke",
                "--steps", "4", "--mesh", "2x4", "--fsdp"],
               extra_env={"XLA_FLAGS":
                          "--xla_force_host_platform_device_count=8"})
    assert "done" in out

"""Validation subsystem: twin correspondence, predictions, bands, gate.

Everything up to the jax-marked block is numpy-only — the same surface the
CPU-only CI leg gates on. The jax block runs the cheap twin (mamba2)
through both real measurement channels end-to-end.
"""
from __future__ import annotations

import json
import math

import pytest

from repro.validation import (CASE_NAMES, REPORT_PATH, build_case,
                              build_case_report, check_case, check_report,
                              hybrid_step_time, load_report, predict_case,
                              trimmed_mean, validation_band,
                              validation_cases, validation_repeats,
                              validation_warmup)
from repro.validation.measure import REPEATS_ENV_VAR, WARMUP_ENV_VAR
from repro.validation.report import (BAND_ENV_VAR, BYTES_FACTOR_ENV_VAR,
                                     WALL_BAND_ENV_VAR, bytes_factor,
                                     wall_band)
from repro.workloads.scenarios import get_scenario


# ------------------------------ twins ----------------------------------------
def test_every_case_twin_certifies():
    """Building a case re-runs the closed-form-vs-graph certification."""
    for case in validation_cases():
        assert case.name in CASE_NAMES
        assert case.steps_per_iter == 1


def test_serving_twin_correspondence_values():
    """The serving twin's two halves agree on hand-checkable numbers:
    2 layers of d=768 with a 2048-slot KV cache plus the LM head."""
    twin = get_scenario("serving").executable_twin()
    got = twin.assert_correspondence()
    d, kv_len, vocab = 768, 2048, 32000
    per_layer = (2 * d * 3 * d          # QKV (q + 2kv, n_kv == n_heads)
                 + 4 * kv_len * d       # decode attention over the cache
                 + 2 * d * d            # output projection
                 + 2 * 3 * d * 3072)    # gated FFN
    head = 2 * d + 2 * d * vocab        # embed + LM head
    assert got["flops_per_token"] == pytest.approx(2 * per_layer + head)
    assert got["kv_bytes_per_request"] == pytest.approx(
        2 * 2 * kv_len * d * 2)         # layers x K&V x slots x d x bf16


def test_twin_correspondence_catches_drift(monkeypatch):
    """A twin whose halves disagree must refuse to certify. Both halves
    derive from one config, so genuine construction can't drift — fake a
    closed-form regression and prove the certification catches it."""
    twin = get_scenario("serving").executable_twin()
    monkeypatch.setattr(type(twin), "flops_per_token", lambda self: 123.0)
    with pytest.raises(AssertionError):
        twin.assert_correspondence()


def test_unlisted_scenario_has_no_twin():
    with pytest.raises(NotImplementedError):
        get_scenario("llm").executable_twin()


# ------------------------------ predictions ----------------------------------
def test_predict_case_terms_partition_step_time():
    for case in validation_cases():
        p = predict_case(case, flop_rate=1e11, mem_bw=4e9)
        assert p["flops"] > 0 and p["bytes"] > 0
        assert p["collective_bytes"] == 0.0
        total = p["t_compute"] + p["t_memory"] + p["t_collective"]
        assert total == pytest.approx(p["step_time"], rel=1e-9)
        # a one-chip plan moves no link bytes, so no collective time
        assert p["t_collective"] == 0.0


def test_predict_case_scales_with_host_rates():
    """Twice the machine, at most half the time (roofline monotonicity)."""
    case = build_case("serving")
    slow = predict_case(case, flop_rate=5e10, mem_bw=2e9)
    fast = predict_case(case, flop_rate=1e11, mem_bw=4e9)
    assert fast["step_time"] == pytest.approx(slow["step_time"] / 2)
    assert fast["flops"] == slow["flops"]      # counts are machine-free


# ------------------------------ protocol knobs -------------------------------
def test_protocol_env_knobs(monkeypatch):
    monkeypatch.delenv(REPEATS_ENV_VAR, raising=False)
    monkeypatch.delenv(WARMUP_ENV_VAR, raising=False)
    assert validation_repeats() == 16
    assert validation_warmup() == 2
    monkeypatch.setenv(REPEATS_ENV_VAR, "4")
    monkeypatch.setenv(WARMUP_ENV_VAR, "0")
    assert validation_repeats() == 4
    assert validation_warmup() == 0
    monkeypatch.setenv(REPEATS_ENV_VAR, "fast")
    with pytest.raises(ValueError, match=REPEATS_ENV_VAR):
        validation_repeats()
    monkeypatch.setenv(REPEATS_ENV_VAR, "0")
    with pytest.raises(ValueError, match=REPEATS_ENV_VAR):
        validation_repeats()


def test_band_env_knobs(monkeypatch):
    for var in (BAND_ENV_VAR, BYTES_FACTOR_ENV_VAR, WALL_BAND_ENV_VAR):
        monkeypatch.delenv(var, raising=False)
    assert validation_band() == 0.25
    assert bytes_factor() == 24.0
    assert wall_band() == 2.5
    monkeypatch.setenv(BAND_ENV_VAR, "0.1")
    assert validation_band() == 0.1
    monkeypatch.setenv(WALL_BAND_ENV_VAR, "not-a-band")
    with pytest.raises(ValueError, match=WALL_BAND_ENV_VAR):
        wall_band()
    monkeypatch.setenv(BYTES_FACTOR_ENV_VAR, "0.5")
    with pytest.raises(ValueError, match=BYTES_FACTOR_ENV_VAR):
        bytes_factor()


def test_trimmed_mean():
    assert trimmed_mean([1.0] * 10) == 1.0
    # one outlier in ten lands in the trimmed tail
    assert trimmed_mean([1.0] * 9 + [100.0]) == 1.0
    assert trimmed_mean([5.0]) == 5.0
    with pytest.raises(ValueError):
        trimmed_mean([])


# ------------------------------ the gate -------------------------------------
def _row(**over):
    predicted = {"flops": 1e9, "bytes": 1e8, "collective_bytes": 0.0,
                 "t_compute": 0.01, "t_memory": 0.02, "t_collective": 0.0,
                 "step_time": 0.03}
    dry = {"flops": 1.05e9, "bytes": 1.2e9, "collective_bytes": 0.0}
    wall = {"tpot": 0.3}
    cal = {"flop_rate": 1e11, "mem_bw": 4e9}
    row = build_case_report("synthetic", predicted, dry, wall, cal,
                            wall_gate=True)
    row["ratios"].update(over.pop("ratios", {}))
    row.update(over)
    return row


def test_check_case_passes_in_band():
    assert check_case(_row()) == []


def test_check_case_flags_each_band():
    bad_flops = check_case(_row(ratios={"flops": 1.5}))
    assert any("flops" in p for p in bad_flops)
    bad_bytes = check_case(_row(ratios={"bytes": 50.0}))
    assert any("bytes" in p for p in bad_bytes)
    assert any("bytes" in p
               for p in check_case(_row(ratios={"bytes": 0.5})))
    bad_coll = check_case(_row(collective_delta_bytes=64.0))
    assert any("collective" in p for p in bad_coll)
    bad_comp = check_case(_row(ratios={"compute_term": 5.0}))
    assert any("compute" in p for p in bad_comp)
    bad_hyb = check_case(_row(ratios={"hybrid": 10.0}))
    assert any("hybrid" in p for p in bad_hyb)


def test_wall_gate_flag_scopes_the_hybrid_band():
    """Ungated cases record the hybrid ratio but are not failed on it."""
    row = _row(ratios={"hybrid": 10.0})
    row["wall_gate"] = False
    assert check_case(row) == []
    # the one-sided compute-term lower bound still applies everywhere
    row = _row(ratios={"hybrid": 10.0, "compute_term": 5.0})
    row["wall_gate"] = False
    assert len(check_case(row)) == 1


def test_hybrid_step_time_is_the_roofline_max():
    dry = {"flops": 8e8, "bytes": 3e9}
    assert hybrid_step_time(dry, 1e11, 4e9) == pytest.approx(3e9 / 4e9)
    assert hybrid_step_time(dry, 1e9, 1e12) == pytest.approx(8e8 / 1e9)


# ------------------------------ committed baseline ---------------------------
def test_committed_baseline_passes_the_gate():
    """BENCH_validation.json must gate green with fresh predictions —
    the no-jax CI leg in miniature."""
    base = load_report()
    assert {row["case"] for row in base["cases"]} == set(CASE_NAMES)
    rows = []
    for brow in base["cases"]:
        case = build_case(brow["case"])
        cal = base["calibration"]
        predicted = predict_case(case, cal["flop_rate"], cal["mem_bw"])
        rows.append(build_case_report(brow["case"], predicted,
                                      brow["dryrun"], None, None,
                                      case.twin.wall_gate))
    assert check_report({"cases": rows}) == []


def test_committed_baseline_wall_ratios_recorded():
    """The committed wall-clock channel must carry the paper's headline
    comparison: per-term ratios present, the gated case inside the band."""
    base = load_report()
    wband = base["bands"]["wall_band"]
    gated = [r for r in base["cases"] if r["wall_gate"]]
    assert gated, "at least one case must gate the wall-clock channel"
    for row in base["cases"]:
        assert row["wallclock"]["tpot"] > 0
        assert "compute_term" in row["ratios"]
        assert "hybrid" in row["ratios"]
    for row in gated:
        assert 1.0 / wband <= row["ratios"]["hybrid"] <= wband


# ------------------------------ jax channels ---------------------------------
jax = pytest.importorskip("jax")


def test_dryrun_channel_within_band_cheap_twin():
    from repro.validation import measure_dryrun
    case = build_case("mamba2")
    dry = measure_dryrun(case)
    assert dry["collective_bytes"] == 0.0
    ratio = dry["flops"] / case.predicted_flops()
    assert abs(ratio - 1.0) <= validation_band()
    assert dry["bytes"] >= case.predicted_bytes() * 0.75


def test_wallclock_channel_cheap_twin():
    from repro.validation import measure_wallclock
    case = build_case("mamba2")
    wall = measure_wallclock(case, repeats=3, warmup=1)
    assert wall["repeats"] == 3 and wall["tpot"] > 0
    assert wall["ttft"] > 0 and wall["tokens_per_s"] > 0
    assert wall["step_time_min"] <= wall["tpot"] <= wall["step_time_max"]


def test_wallclock_window_guard():
    from repro.validation import measure_wallclock
    case = build_case("mamba2")
    with pytest.raises(ValueError, match="measurement window"):
        measure_wallclock(case, repeats=10_000, warmup=0)

"""End-to-end system behaviour: train → checkpoint → crash → resume → serve,
and the DFModel planner driving a real sharded step."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, synth_batch
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticTokens
from repro.train.fault import StragglerMonitor
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step, train_loop

CFG = get_config("olmo_1b", smoke=True)


def test_train_loop_overfits_tiny_corpus(tmp_path):
    """A tiny model on a repeating batch: loss must drop clearly."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    fixed = synth_batch(CFG, batch=4, seq=32)
    data = iter(lambda: fixed, None)  # same batch forever
    mon = StragglerMonitor()
    params, opt, history = train_loop(
        CFG, params, data, steps=12, opt_cfg=AdamWConfig(lr=3e-3),
        checkpoint_manager=CheckpointManager(tmp_path), checkpoint_every=5,
        straggler_monitor=mon, log_every=0)
    assert history[-1] < history[0] * 0.9
    assert np.isfinite(history).all()


def test_crash_resume_continuity(tmp_path):
    """Training resumed from a checkpoint continues from the same state:
    the resumed run must match the uninterrupted run."""
    mgr = CheckpointManager(tmp_path)
    params = init_params(CFG, jax.random.PRNGKey(1))
    opt = adamw_init(params)
    batches = [synth_batch(CFG, batch=2, seq=16, seed=s) for s in range(6)]
    step_fn = jax.jit(make_train_step(CFG, AdamWConfig(lr=1e-3)))

    # uninterrupted run
    p, o = params, opt
    for b in batches:
        p, o, _ = step_fn(p, o, b)
    ref = p

    # interrupted at step 3 + resumed
    p, o = params, opt
    for b in batches[:3]:
        p, o, _ = step_fn(p, o, b)
    mgr.save(3, {"params": p, "opt": o})
    del p, o
    _, tree = mgr.restore(3)
    p, o = tree["params"], tree["opt"]
    o["step"] = jnp.asarray(o["step"], jnp.int32)
    for b in batches[3:]:
        p, o, _ = step_fn(p, o, b)

    for a, b_ in zip(jax.tree.leaves(ref), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_planner_plans_every_arch_cell():
    """DFModel's planner must produce a finite prediction for every assigned
    (arch × shape) cell — the analytical half of the dry-run."""
    from repro.configs import ARCH_IDS, cells
    from repro.launch.plan import plan_cell
    checked = 0
    for arch in ARCH_IDS:
        if arch == "gpt3_175b":
            continue
        for shape in cells(arch):
            out = plan_cell(arch, shape, multi_pod=False)
            assert "error" not in out, (arch, shape, out)
            key = "iter_time_s" if "iter_time_s" in out else "total_time_s"
            assert out[key] > 0 and np.isfinite(out[key]), (arch, shape)
            checked += 1
    assert checked >= 32


def test_synthetic_stream_feeds_trainer():
    stream = iter(SyntheticTokens(vocab=CFG.vocab, batch=2, seq=16))
    params = init_params(CFG, jax.random.PRNGKey(2))
    step_fn = jax.jit(make_train_step(CFG))
    p, o, m = step_fn(params, adamw_init(params), next(stream))
    assert bool(jnp.isfinite(m["loss"]))

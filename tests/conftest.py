"""Shared fixtures + hypothesis strategies.

IMPORTANT: no XLA_FLAGS here — smoke tests and benches must see the real
single CPU device; only launch/dryrun.py installs the 512 placeholder
devices (and only in its own process).
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import strategies as st

from repro.core.graph import DataflowGraph, Kernel, KernelKind, Tensor


# --------------------------- random DAG strategy ------------------------------
@st.composite
def dags(draw, max_kernels: int = 8, max_edges: int = 12,
         connected_chain: bool = True):
    """Random DAG with kernels k0..k{n-1}; edges only i -> j with i < j, so
    the index order is a valid topological order."""
    n = draw(st.integers(min_value=2, max_value=max_kernels))
    kinds = list(KernelKind)
    kernels = [
        Kernel(f"k{i}",
               flops=draw(st.floats(min_value=1.0, max_value=1e12)),
               kind=draw(st.sampled_from(kinds)),
               weight_bytes=draw(st.floats(min_value=0.0, max_value=1e9)))
        for i in range(n)
    ]
    edges: set[tuple[int, int]] = set()
    if connected_chain:
        edges |= {(i, i + 1) for i in range(n - 1)}
    m_extra = draw(st.integers(min_value=0, max_value=max_edges))
    for _ in range(m_extra):
        i = draw(st.integers(min_value=0, max_value=n - 2))
        j = draw(st.integers(min_value=i + 1, max_value=n - 1))
        edges.add((i, j))
    tensors = [
        Tensor(f"t{i}_{j}", f"k{i}", f"k{j}",
               draw(st.floats(min_value=1.0, max_value=1e9)))
        for (i, j) in sorted(edges)
    ]
    return DataflowGraph(kernels, tensors, "random")


@st.composite
def dags_with_assignments(draw, max_kernels: int = 8, p_max: int = 4):
    """(graph, precedence-feasible assignment vector, p_max)."""
    g = draw(dags(max_kernels=max_kernels))
    # monotone assignment along index order keeps precedence feasible
    assign = []
    cur = 0
    for _ in range(g.n):
        cur = min(cur + draw(st.integers(min_value=0, max_value=1)),
                  p_max - 1)
        assign.append(cur)
    return g, np.array(assign, dtype=np.int64), p_max


@pytest.fixture(scope="session")
def smoke_cfgs():
    from repro.configs import ARCH_IDS, get_config
    return {a: get_config(a, smoke=True) for a in ARCH_IDS}

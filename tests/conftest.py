"""Shared fixtures + hypothesis strategies.

IMPORTANT: no XLA_FLAGS here — smoke tests and benches must see the real
single CPU device; only launch/dryrun.py installs the 512 placeholder
devices (and only in its own process).

``hypothesis`` is a dev-only dependency (requirements-dev.txt). When it is
absent the property-test strategies below degrade to stubs that skip, and
the property-test modules guard themselves with
``pytest.importorskip("hypothesis")`` — collection must never fail on a
missing dev extra.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    st = None
    HAVE_HYPOTHESIS = False

from repro.core.graph import DataflowGraph, Kernel, KernelKind, Tensor


def _build_dag(n: int, edges: set[tuple[int, int]], flops, weights,
               kinds, tensor_bytes) -> DataflowGraph:
    """Assemble the random-DAG fixture; edges only i -> j with i < j, so the
    index order is a valid topological order."""
    kernels = [Kernel(f"k{i}", flops=flops[i], kind=kinds[i],
                      weight_bytes=weights[i]) for i in range(n)]
    tensors = [Tensor(f"t{i}_{j}", f"k{i}", f"k{j}", b)
               for (i, j), b in zip(sorted(edges), tensor_bytes)]
    return DataflowGraph(kernels, tensors, "random")


if HAVE_HYPOTHESIS:
    # ----------------------- random DAG strategy -----------------------------
    @st.composite
    def dags(draw, max_kernels: int = 8, max_edges: int = 12,
             connected_chain: bool = True):
        """Random DAG with kernels k0..k{n-1}; edges only i -> j with i < j,
        so the index order is a valid topological order."""
        n = draw(st.integers(min_value=2, max_value=max_kernels))
        kinds = list(KernelKind)
        flops = [draw(st.floats(min_value=1.0, max_value=1e12))
                 for _ in range(n)]
        weights = [draw(st.floats(min_value=0.0, max_value=1e9))
                   for _ in range(n)]
        kind_choice = [draw(st.sampled_from(kinds)) for _ in range(n)]
        edges: set[tuple[int, int]] = set()
        if connected_chain:
            edges |= {(i, i + 1) for i in range(n - 1)}
        m_extra = draw(st.integers(min_value=0, max_value=max_edges))
        for _ in range(m_extra):
            i = draw(st.integers(min_value=0, max_value=n - 2))
            j = draw(st.integers(min_value=i + 1, max_value=n - 1))
            edges.add((i, j))
        tensor_bytes = [draw(st.floats(min_value=1.0, max_value=1e9))
                        for _ in sorted(edges)]
        return _build_dag(n, edges, flops, weights, kind_choice, tensor_bytes)

    @st.composite
    def dags_with_assignments(draw, max_kernels: int = 8, p_max: int = 4):
        """(graph, precedence-feasible assignment vector, p_max)."""
        g = draw(dags(max_kernels=max_kernels))
        # monotone assignment along index order keeps precedence feasible
        assign = []
        cur = 0
        for _ in range(g.n):
            cur = min(cur + draw(st.integers(min_value=0, max_value=1)),
                      p_max - 1)
            assign.append(cur)
        return g, np.array(assign, dtype=np.int64), p_max
else:
    def dags(*args, **kwargs):  # pragma: no cover - exercised without dev deps
        pytest.skip("hypothesis not installed (pip install -r "
                    "requirements-dev.txt)")

    def dags_with_assignments(*args, **kwargs):  # pragma: no cover
        pytest.skip("hypothesis not installed (pip install -r "
                    "requirements-dev.txt)")


def random_dag(rng: np.random.Generator, max_kernels: int = 8,
               max_edges: int = 12) -> DataflowGraph:
    """Seeded random DAG for the non-hypothesis fallback tests — same shape
    distribution as the ``dags()`` strategy."""
    n = int(rng.integers(2, max_kernels + 1))
    kinds = list(KernelKind)
    flops = rng.uniform(1.0, 1e12, size=n).tolist()
    weights = rng.uniform(0.0, 1e9, size=n).tolist()
    kind_choice = [kinds[int(rng.integers(len(kinds)))] for _ in range(n)]
    edges = {(i, i + 1) for i in range(n - 1)}
    for _ in range(int(rng.integers(0, max_edges + 1))):
        i = int(rng.integers(0, n - 1))
        j = int(rng.integers(i + 1, n))
        edges.add((i, j))
    tensor_bytes = rng.uniform(1.0, 1e9, size=len(edges)).tolist()
    return _build_dag(n, edges, flops, weights, kind_choice, tensor_bytes)


@pytest.fixture(scope="session")
def smoke_cfgs():
    from repro.configs import ARCH_IDS, get_config
    return {a: get_config(a, smoke=True) for a in ARCH_IDS}

"""Budgeted search-policy tests (``repro.search`` + ``DSEEngine.search``).

The house rule under test: on every smoke scenario, every shipped policy
must recover the exhaustive pruned sweep's true argmin — the engine
certifies the winner against a full-grid evaluation through the
identical machinery and raises on a miss, so a passing test IS the
certification.  Alongside it: seeded determinism (same seed → same
evaluation sequence → same winner), exactly-once budget accounting
(misbehaving policies raise, honest ones never exceed the budget), the
cheap-bound/full-pricing agreement SuccessiveHalving's single promotion
round rests on, the scaled-variant grid generator, the memo-store
harvest feeding the plan-level surrogate, and the env-var spelling
fixes (``DFMODEL_PRUNE=false`` must disable pruning, unknown spellings
must raise).

The CI search-certification legs re-run this file with
``DFMODEL_TEST_MP_CONTEXT`` set to fork and forkserver — the two
transports the engine's start-method auto-pick chooses between.
"""
from __future__ import annotations

import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import DSEEngine, SweepSpec, clear_caches
from repro.core.interchip import default_prune, resolve_prune
from repro.core.memo import GLOBAL_CACHE, SolveCache
from repro.core.memo_store import MmapStore
from repro.core.pricing import default_backend
from repro.search import (DenseGridSpec, Observation, RandomSearch,
                          SearchPolicy, SuccessiveHalving, SurrogateSearch,
                          cell_features, fit_plan_ridge, plan_feature_rows,
                          scaled_name)
from repro.search.surrogate import PLAN_FEATURE_FIELDS, RidgeModel
from repro.systems.chips import (CHIPS, INTERCONNECTS, MEMORIES,
                                 resolve_chip, resolve_interconnect,
                                 resolve_memory)
from repro.workloads.llm import LLAMA_68M, gpt_workload
from repro.workloads.scenarios import get_scenario, scenario_names


# module-level so the workload builder is picklable under spawn semantics
def _tiny_work(system):
    return gpt_workload(LLAMA_68M, global_batch=64, microbatch=1)


SMOKE_SPEC = SweepSpec(n_chips=16, chips=("H100", "SN30"),
                       topologies=("torus2d", "dgx2"),
                       mem_net=(("DDR", "PCIe"), ("HBM", "NVLink")),
                       max_tp=16)


def _engine(**kwargs) -> DSEEngine:
    env_ctx = os.environ.get("DFMODEL_TEST_MP_CONTEXT")
    if env_ctx:
        kwargs.setdefault("mp_context", env_ctx)
    kwargs.setdefault("parallel", False)
    return DSEEngine(**kwargs)


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_caches()
    yield
    clear_caches()


# --- certification: every policy, every smoke scenario -----------------------
def _policies(n: int):
    """One instance of each shipped policy plus its certification budget.

    Random and surrogate get the full grid (their certification is an
    exhaustive walk in policy order); halving runs genuinely
    budget-limited off its cheap bound.
    """
    return [(RandomSearch(seed=0, batch_size=8), n),
            (SuccessiveHalving(eta=4), max(1, math.ceil(n / 4))),
            (SurrogateSearch(seed=0, batch_size=6, min_train=6), n)]


@pytest.mark.parametrize("name", scenario_names())
def test_every_policy_certifies_on_every_smoke_scenario(name):
    sc = get_scenario(name, smoke=True)
    eng = _engine()
    n = len(sc.spec.grid())
    for policy, budget in _policies(n):
        res = eng.search(sc.work_fn, sc.spec, policy=policy, budget=budget)
        assert res.certified
        assert res.best_index == res.oracle_index
        assert res.evals_used <= res.budget <= n


def test_halving_budget_one_still_finds_argmin():
    # the cheap bound is the exact objective prefix, so the true argmin
    # is the FIRST cell halving promotes — certification holds at budget 1
    res = _engine().search(_tiny_work, SMOKE_SPEC,
                           policy=SuccessiveHalving(eta=4), budget=1)
    assert res.certified and res.evals_used == 1
    assert res.best_index == res.oracle_index


def test_search_result_bookkeeping():
    n = len(SMOKE_SPEC.grid())
    seen = []
    res = _engine().search(_tiny_work, SMOKE_SPEC,
                           policy=RandomSearch(seed=1, batch_size=3),
                           budget=n, progress=seen.append)
    assert res.evals_used == n == len(res.evaluated)
    assert res.rounds == seen
    assert [r["round"] for r in res.rounds] == list(
        range(1, len(res.rounds) + 1))
    assert res.rounds[-1]["evals"] == n
    assert res.rounds[-1]["eta_s"] == 0.0
    assert all(r["elapsed_s"] <= res.seconds for r in res.rounds)
    best = res.evaluated[res.best_index]
    assert res.best_objective == (best.feasible, best.iter_time)
    assert res.best_point is best.point


# --- seeded determinism ------------------------------------------------------
@pytest.mark.parametrize("make", [
    lambda seed: RandomSearch(seed=seed, batch_size=4),
    lambda seed: SurrogateSearch(seed=seed, batch_size=4, min_train=4),
])
def test_same_seed_same_evaluation_sequence(make):
    n = len(SMOKE_SPEC.grid())
    eng = _engine()
    runs = [eng.search(_tiny_work, SMOKE_SPEC, policy=make(seed=5),
                       budget=n, certify=False) for _ in range(2)]
    # dict preserves insertion order == evaluation order
    assert list(runs[0].evaluated) == list(runs[1].evaluated)
    assert runs[0].best_index == runs[1].best_index
    assert runs[0].best_objective == runs[1].best_objective


def test_different_seeds_differ_somewhere():
    n = len(SMOKE_SPEC.grid())
    eng = _engine()
    orders = [list(eng.search(_tiny_work, SMOKE_SPEC,
                              policy=RandomSearch(seed=s, batch_size=4),
                              budget=n, certify=False).evaluated)
              for s in range(4)]
    assert any(o != orders[0] for o in orders[1:])


# --- exactly-once budget accounting ------------------------------------------
class _Misbehaving(SearchPolicy):
    name = "misbehaving"

    def __init__(self, proposals):
        self._proposals = list(proposals)

    def ask(self):
        return self._proposals.pop(0) if self._proposals else []


@pytest.mark.parametrize("proposals, msg", [
    ([[0, 1], [1, 2]], "more than once"),          # duplicate across rounds
    ([[3, 3]], "more than once"),                  # duplicate within a round
    ([[99]], "out-of-range"),
    ([[-1]], "out-of-range"),
    ([[0, 1, 2], [3, 4, 5]], "exceeded the evaluation budget"),
])
def test_contract_violations_raise(proposals, msg):
    with pytest.raises(RuntimeError, match=msg):
        _engine().search(_tiny_work, SMOKE_SPEC,
                         policy=_Misbehaving(proposals), budget=4,
                         certify=False)


def test_budget_clamped_to_grid_and_validated():
    n = len(SMOKE_SPEC.grid())
    res = _engine().search(_tiny_work, SMOKE_SPEC,
                           policy=RandomSearch(seed=0), budget=10 * n,
                           certify=False)
    assert res.budget == n and res.evals_used == n
    with pytest.raises(ValueError, match="budget"):
        _engine().search(_tiny_work, SMOKE_SPEC,
                         policy=RandomSearch(), budget=0)


def test_empty_ask_ends_search_without_spending_budget():
    res = _engine().search(_tiny_work, SMOKE_SPEC,
                           policy=_Misbehaving([[0, 1]]), budget=6,
                           certify=False)
    assert res.evals_used == 2
    assert res.best_index in (0, 1)


# --- the cheap bound is the exact objective prefix ---------------------------
def test_cheap_bound_matches_full_pricing():
    grid = SMOKE_SPEC.grid()
    eng = _engine()
    captured = {}

    class _Capture(SearchPolicy):
        name = "capture"

        def reset(self, ctx):
            super().reset(ctx)
            captured["bounds"] = ctx.cheap_bound(range(ctx.n_points))

        def ask(self):
            if captured.get("asked"):
                return []
            captured["asked"] = True
            return list(range(self.ctx.n_points))

    res = eng.search(_tiny_work, SMOKE_SPEC, policy=_Capture(),
                     budget=len(grid))
    for i, (infeasible, lb) in enumerate(captured["bounds"]):
        obs = res.evaluated[i]
        assert infeasible == (not obs.feasible)
        if obs.point is not None:
            # selection-column iter_time is bit-identical to full pricing
            assert lb == obs.iter_time
    assert res.cheap_evals == len(grid)


def test_observation_objective_orders_infeasible_last():
    cell = SMOKE_SPEC.grid()[0]
    good = Observation(index=1, cell=cell, feasible=True, iter_time=2.0,
                       utilization=0.5, point=None)
    slow = Observation(index=0, cell=cell, feasible=True, iter_time=3.0,
                       utilization=0.5, point=None)
    infeasible = Observation(index=2, cell=cell, feasible=False,
                             iter_time=1.0, utilization=0.5, point=None)
    undecomposable = Observation(index=3, cell=cell, feasible=False,
                                 iter_time=math.inf, utilization=0.0,
                                 point=None)
    ranked = sorted([undecomposable, infeasible, slow, good],
                    key=lambda o: o.objective)
    assert [o.index for o in ranked] == [1, 0, 2, 3]


# --- dense scaled-variant grids ----------------------------------------------
def test_scaled_name_roundtrip_and_validation():
    assert scaled_name("H100", 1.0) == "H100"
    assert scaled_name("H100", 1.25) == "H100@x1.25"
    with pytest.raises(ValueError):
        resolve_chip("H100@x0")
    with pytest.raises(ValueError):
        resolve_chip("H100@xfast")
    with pytest.raises(KeyError):
        resolve_chip("NoSuchChip@x1.5")


def test_scaled_resolvers_scale_the_right_fields():
    chip = resolve_chip("H100@x1.25")
    base = CHIPS["H100"]
    assert math.isclose(chip.tile_flops, 1.25 * base.tile_flops,
                        rel_tol=1e-12)
    assert chip.price == base.price and chip.power == base.power
    mem = resolve_memory("HBM@x2")
    assert math.isclose(mem.bandwidth, 2 * MEMORIES["HBM"].bandwidth,
                        rel_tol=1e-12)
    assert math.isclose(mem.capacity, 2 * MEMORIES["HBM"].capacity,
                        rel_tol=1e-12)
    net = resolve_interconnect("NVLink@x1.5")
    assert math.isclose(net.bandwidth,
                        1.5 * INTERCONNECTS["NVLink"].bandwidth,
                        rel_tol=1e-12)
    assert net.latency == INTERCONNECTS["NVLink"].latency
    # unscaled names resolve to the registry objects themselves
    assert resolve_chip("H100") is CHIPS["H100"]


def test_dense_grid_spec_shape():
    dg = DenseGridSpec()
    spec = dg.spec()
    assert dg.n_cells() == len(spec.grid()) == 864  # >= 10x the paper's 80
    assert len(set(spec.chips)) == len(spec.chips)
    assert len(set(spec.mem_net)) == len(spec.mem_net)


def test_halving_certifies_dense_grid_within_eval_budget():
    # the acceptance figure: a certified winner on the >= 800-point grid
    # with <= 20% of exhaustive full evaluations
    spec = DenseGridSpec().spec()
    n = len(spec.grid())
    res = _engine().search(_tiny_work, spec, policy=SuccessiveHalving(eta=8),
                           budget=max(1, n // 5))
    assert res.certified and res.best_index == res.oracle_index
    assert res.evals_used / n <= 0.2
    assert res.cheap_evals == n


# --- surrogate internals -----------------------------------------------------
def test_ridge_recovers_linear_map():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(64, 3))
    y = X @ [2.0, -1.0, 0.5] + 3.0
    model = RidgeModel.fit(X, y, lam=1e-8)
    assert np.allclose(model.predict(X), y, atol=1e-6)


def test_cell_features_are_finite_and_scale_aware():
    vocab = {"torus2d": 0, "dgx2": 1}
    f1 = cell_features(("H100", "HBM", "NVLink", "dgx2"), 64, vocab)
    f2 = cell_features(("H100@x2", "HBM", "NVLink", "dgx2"), 64, vocab)
    assert np.all(np.isfinite(f1)) and f1.shape == f2.shape
    assert f2[0] > f1[0]                      # scaled chip: more flops
    assert np.array_equal(f1[1:], f2[1:])     # everything else unchanged


def test_surrogate_validates_warm_start_and_explore():
    with pytest.raises(ValueError, match="explore"):
        SurrogateSearch(explore=1.5)
    bad = SurrogateSearch(warm_start=(np.zeros((2, 3)), np.zeros(2)))
    with pytest.raises(ValueError, match="warm_start"):
        _engine().search(_tiny_work, SMOKE_SPEC, policy=bad,
                         budget=4, certify=False)


# --- memo-store harvest + plan-level surrogate -------------------------------
def test_harvest_local_entries():
    cache = SolveCache()
    cache.get_or_compute("spacex", ("a",), lambda: 1)
    cache.get_or_compute("spacex", ("b",), lambda: 2)
    cache.get_or_compute("other", ("a",), lambda: 3)
    assert sorted(cache.harvest("spacex")) == [(("a",), 1), (("b",), 2)]
    assert cache.harvest("empty") == []


def test_harvest_sees_shared_store_entries():
    store = MmapStore()
    try:
        writer = SolveCache()
        writer.attach_shared(store)
        writer.get_or_compute("spacex", ("k",), lambda: 42)
        reader = SolveCache()
        reader.attach_shared(store)
        assert reader.harvest("spacex") == [(("k",), 42)]
        # local entries win over (identical) shared ones: no duplicates
        writer_rows = writer.harvest("spacex")
        assert writer_rows == [(("k",), 42)]
    finally:
        store.close()


def test_plan_feature_rows_and_ridge_from_sweep():
    assert plan_feature_rows()[0].shape == (0, len(PLAN_FEATURE_FIELDS))
    assert fit_plan_ridge() is None
    eng = _engine()
    res = eng.search(_tiny_work, SMOKE_SPEC, policy=RandomSearch(seed=0),
                     budget=len(SMOKE_SPEC.grid()), certify=False)
    X, y = plan_feature_rows(GLOBAL_CACHE)
    assert len(X) == len(y) > 0 and X.shape[1] == len(PLAN_FEATURE_FIELDS)
    assert np.all(np.isfinite(X)) and np.all(y > 0)
    model = fit_plan_ridge(GLOBAL_CACHE)
    pred = model.predict(X)
    # sanity, not accuracy: the fit explains more variance than the mean
    target = np.log10(y)
    assert np.mean((pred - target) ** 2) < np.var(target)
    del res


# --- env-var spelling regressions --------------------------------------------
@pytest.mark.parametrize("spelling, mode", [
    ("on", "on"), ("1", "on"), ("true", "on"), ("yes", "on"),
    ("off", "off"), ("0", "off"), ("false", "off"), ("no", "off"),
    ("TRUE", "on"), (" False ", "off"),
])
def test_prune_env_spellings(monkeypatch, spelling, mode):
    monkeypatch.setenv("DFMODEL_PRUNE", spelling)
    assert default_prune() == mode
    assert resolve_prune("auto") is (mode == "on")


@pytest.mark.parametrize("bad", ["disabled", "2", "offf", "none"])
def test_prune_env_unknown_raises(monkeypatch, bad):
    monkeypatch.setenv("DFMODEL_PRUNE", bad)
    with pytest.raises(ValueError, match="unknown DFMODEL_PRUNE"):
        default_prune()
    with pytest.raises(ValueError, match="unknown DFMODEL_PRUNE"):
        resolve_prune("auto")


def test_prune_env_unset_or_empty_defaults_on(monkeypatch):
    monkeypatch.delenv("DFMODEL_PRUNE", raising=False)
    assert default_prune() == "on"
    monkeypatch.setenv("DFMODEL_PRUNE", "")
    assert default_prune() == "on"


@pytest.mark.parametrize("off_spelling", ["false", "0", "no"])
def test_prune_env_false_actually_disables_pruning(monkeypatch,
                                                   off_spelling):
    # the regression this PR fixes: "false" used to be read as enabled
    stats = {}
    for spelling in (off_spelling, "true"):
        monkeypatch.setenv("DFMODEL_PRUNE", spelling)
        clear_caches()
        eng = DSEEngine(parallel=False, phased=True)
        eng.sweep(_tiny_work, SMOKE_SPEC)
        stats[spelling] = eng.last_plan_stats
    off, on = stats[off_spelling], stats["true"]
    assert off["prune"] is False
    assert off["priced"] == off["enumerated"]        # nothing filtered
    assert on["prune"] is True
    assert on["priced"] < on["enumerated"]           # pruning engaged


@pytest.mark.parametrize("bad", ["cuda", "numpyy", "torch"])
def test_pricing_backend_env_unknown_raises(monkeypatch, bad):
    monkeypatch.setenv("DFMODEL_PRICING_BACKEND", bad)
    with pytest.raises(ValueError, match="unknown DFMODEL_PRICING_BACKEND"):
        default_backend()


def test_pricing_backend_env_known_spellings(monkeypatch):
    monkeypatch.delenv("DFMODEL_PRICING_BACKEND", raising=False)
    assert default_backend() == "numpy"
    for backend in ("numpy", "jax", "pallas", "pallas-compiled"):
        monkeypatch.setenv("DFMODEL_PRICING_BACKEND", backend)
        assert default_backend() == backend
    monkeypatch.setenv("DFMODEL_PRICING_BACKEND", "NumPy")
    assert default_backend() == "numpy"
    monkeypatch.setenv("DFMODEL_PRICING_BACKEND", "Pallas-Compiled")
    assert default_backend() == "pallas-compiled"


# --- start-method auto-pick (fork-after-jax fix) -----------------------------
def _probe_start_method(preamble: str) -> str:
    code = (f"import sys\n{preamble}\n"
            "from repro.core.dse_engine import DSEEngine\n"
            "print(DSEEngine()._start_method())")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    env.pop("DFMODEL_TEST_MP_CONTEXT", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    return out.stdout.strip()


@pytest.mark.skipif("fork" not in
                    __import__("multiprocessing").get_all_start_methods(),
                    reason="platform has no fork")
def test_auto_start_method_prefers_fork_without_jax():
    assert _probe_start_method(
        "assert 'jax' not in sys.modules") == "fork"


@pytest.mark.skipif("forkserver" not in
                    __import__("multiprocessing").get_all_start_methods(),
                    reason="platform has no forkserver")
def test_auto_start_method_prefers_forkserver_once_jax_loaded():
    pytest.importorskip("jax")
    assert _probe_start_method("import jax") == "forkserver"


def test_explicit_mp_context_still_wins():
    assert DSEEngine(mp_context="spawn")._start_method() == "spawn"

"""Reproduction of the paper's headline quantitative claims.

Each test pins one claim from the paper to the analytical core. Exact
magnitudes depend on constants the paper does not publish, so tests assert
the *direction* and the *order of magnitude band* of each claim; the
benchmark harness reports the exact reproduced numbers.
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.core.costpower import silicon_power_w
from repro.core.intrachip import (evaluate_intra_assignment,
                                  optimize_intra_chip)
from repro.core.sharding import solve_sharding
from repro.systems.chips import DDR, ICI, PCIE, SN10
from repro.systems.system import SystemSpec
from repro.systems.topology import ring, torus2d
from repro.workloads.llm import GPT3_175B, gpt_layer_graph

# §VII experiment: GPT3 175B on 8 SN10 RDUs, DDR 200GB/s, PCIe 25GB/s
DDR_200 = dataclasses.replace(DDR, bandwidth=200e9)
RING8 = ring(8, PCIE)
TORUS42 = torus2d(8, PCIE)

VENDOR = {"LN1": 0, "QKV": 0, "MHA1": 1, "Softmax": 1, "MHA2": 1,
          "Proj": 1, "Add1": 1, "LN2": 1, "FFN0": 2, "FFN1": 3, "Add2": 3}


def _mapping_times(tp: int, topo):
    """(kbk, vendor, dfmodel) per-microbatch times for one GPT3-175B layer."""
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1))
    sol = solve_sharding(g, tp, topo, list(range(len(topo.dims))))
    sharded = g.scaled(flop_scale=1.0 / tp, bytes_scale=1.0 / tp)
    kbk = optimize_intra_chip(sharded, SN10, DDR_200, h_n=sol.h_n,
                              h_m=sol.h_m, mode="kbk")
    vendor = evaluate_intra_assignment(
        sharded, [VENDOR[k.name] for k in sharded.kernels], SN10, DDR_200,
        h_n=sol.h_n, h_m=sol.h_m)
    df = optimize_intra_chip(sharded, SN10, DDR_200, h_n=sol.h_n,
                             h_m=sol.h_m, p_max=8)
    return kbk.total_time, vendor.total_time, df.total_time


def test_table_vi_mapping_ladder():
    """Table VI: dataflow vs non-dataflow 4.05×; DFModel vs vendor 1.19×;
    4×2 torus vs 8×1 ring 1.28×; cumulative 6.13×."""
    kbk, vendor, df81 = _mapping_times(8, RING8)
    # step 1: vendor dataflow vs non-dataflow — paper 4.05× *against
    # Calculon's own mapping*. Our kbk baseline reuses DFModel's utilization
    # model, so it is less pessimistic than Calculon (which under-predicts
    # dataflow systems by 60%, Fig 6); the reproduced advantage is smaller
    # but strictly > 1 (see EXPERIMENTS.md §Validation).
    s1 = kbk / vendor
    assert 1.4 < s1 < 8.0, s1
    # step 2: DFModel mapping vs vendor on the same ring — paper 1.19×
    s2 = vendor / df81
    assert 1.0 <= s2 < 2.0, s2
    # step 3: 4×2 torus — TP drops 8→4, DP=2 replicas run concurrently, so
    # system throughput doubles per microbatch-time: paper 1.28×
    _, _, df42 = _mapping_times(4, TORUS42)
    s3 = 2.0 * df81 / df42
    assert 1.0 < s3 < 2.5, s3
    total = 2.0 * kbk / df42
    assert 2.0 < total < 12.0, total  # paper: 6.13×


def test_fig19_dataflow_upper_bounds_nondataflow():
    """Fig 19: dataflow ≥ non-dataflow on every memory design point, with
    the average advantage in the paper's 1.63× band."""
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1)).scaled(
        1.0 / 8, 1.0 / 8)
    chip300 = dataclasses.replace(SN10, tiles=1000,
                                  tile_flops=300e12 / 1000)
    ratios = []
    for sram_mb in (150, 300, 500):
        for bw_gb in (100, 300, 600):
            chip = dataclasses.replace(chip300, sram_capacity=sram_mb * 1e6)
            mem = dataclasses.replace(DDR, bandwidth=bw_gb * 1e9)
            df = optimize_intra_chip(g, chip, mem)
            kbk = optimize_intra_chip(g, chip, mem, mode="kbk")
            assert df.total_time <= kbk.total_time * (1 + 1e-9)
            ratios.append(kbk.total_time / df.total_time)
    avg = sum(ratios) / len(ratios)
    assert 1.2 < avg < 4.0, avg  # paper: 1.63×


def test_fig19_sram_and_bandwidth_trends():
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1)).scaled(
        1.0 / 8, 1.0 / 8)
    # dataflow gains from SRAM (more fusion)
    t_small = optimize_intra_chip(
        g, dataclasses.replace(SN10, sram_capacity=150e6), DDR_200).total_time
    t_large = optimize_intra_chip(
        g, dataclasses.replace(SN10, sram_capacity=500e6), DDR_200).total_time
    assert t_large <= t_small * (1 + 1e-9)
    # kbk gains from DRAM bandwidth
    k_slow = optimize_intra_chip(
        g, SN10, dataclasses.replace(DDR, bandwidth=100e9),
        mode="kbk").total_time
    k_fast = optimize_intra_chip(
        g, SN10, dataclasses.replace(DDR, bandwidth=600e9),
        mode="kbk").total_time
    assert k_fast < k_slow


def test_fig9_power_superlinearity():
    """Fig 9: silicon power grows superlinearly with compute throughput."""
    p1 = silicon_power_w(100)
    p2 = silicon_power_w(200)
    p4 = silicon_power_w(400)
    assert p2 / p1 > 2 * 0.99        # ≥ linear
    assert p4 / p2 > p2 / p1          # accelerating
    # Table V anchors within a generous band
    assert 500 < silicon_power_w(993) < 1000      # H100: 700 W
    assert 100 < silicon_power_w(275) < 250       # TPUv4: 192 W
    assert 10_000 < silicon_power_w(7500) < 25_000  # WSE-2


def test_dataflow_mapping_reduces_memory_boundedness():
    """Fig 18 narrative: kbk is heavily memory-bound; the dataflow mapping
    moves the bottleneck away from memory."""
    g = gpt_layer_graph(dataclasses.replace(GPT3_175B, batch=1)).scaled(
        1.0 / 8, 1.0 / 8)
    kbk = optimize_intra_chip(g, SN10, DDR_200, mode="kbk")
    df = optimize_intra_chip(g, SN10, DDR_200)
    mem_frac_kbk = kbk.t_mem.sum() / kbk.t_critical.sum()
    mem_frac_df = df.t_mem.sum() / (df.t_comp.sum() + df.t_mem.sum()
                                    + df.t_net.sum())
    # kbk spends a large share of its time on DRAM; fusion removes most of it
    assert mem_frac_kbk > 0.35
    assert mem_frac_df < mem_frac_kbk
    assert df.dram_traffic < kbk.dram_traffic / 2

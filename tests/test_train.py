"""Training substrate tests: optimizer, accumulation, checkpointing,
fault tolerance, compression, data pipeline."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import init_params, synth_batch
from repro.parallel.compression import (compress_tree, decompress_tree,
                                        dequantize_int8, quantize_int8)
from repro.train.checkpoint import CheckpointManager
from repro.train.data import MemmapTokens, SyntheticTokens
from repro.train.fault import Heartbeat, StragglerMonitor, retry_step
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule, global_norm)
from repro.train.trainer import make_train_step

CFG = get_config("olmo_1b", smoke=True)
KEY = jax.random.PRNGKey(0)


def test_grad_accumulation_equivalence():
    """accum=2 must produce the same update as accum=1 on the same batch."""
    params = init_params(CFG, KEY)
    batch = synth_batch(CFG, batch=4, seq=32)
    opt_cfg = AdamWConfig(lr=1e-3)
    s1 = jax.jit(make_train_step(CFG, opt_cfg, accum=1))
    s2 = jax.jit(make_train_step(CFG, opt_cfg, accum=2))
    p1, o1, m1 = s1(params, adamw_init(params), batch)
    p2, o2, m2 = s2(params, adamw_init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # Adam's rsqrt(v)+eps amplifies bf16 rounding on near-zero grads;
        # equivalence is up to dtype noise, not bitwise
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_adamw_grad_clipping():
    params = {"w": jnp.ones((4,), jnp.float32)}
    huge = {"w": jnp.full((4,), 1e6, jnp.float32)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    state = adamw_init(params)
    p2, _ = adamw_update(params, huge, state, cfg)
    # post-clip global norm is 1 ⇒ first Adam step magnitude ≈ lr
    assert float(jnp.abs(p2["w"] - params["w"]).max()) < 1.5


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, warmup=10, total=100)
    assert float(fn(jnp.int32(0))) == pytest.approx(0.0)
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(fn(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(fn(jnp.int32(55))) > float(fn(jnp.int32(90)))


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    params = init_params(CFG, KEY)
    opt = adamw_init(params)
    mgr.save(7, {"params": params, "opt": opt})
    step, tree = mgr.restore()
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.arange(4)}
    for s in (1, 2, 3):
        mgr.save(s, tree)
    assert mgr.latest_step() == 3
    ckpts = sorted(tmp_path.glob("ckpt_*.npz"))
    assert len(ckpts) == 2  # oldest garbage-collected


def test_checkpoint_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    for s in range(3):
        mgr.save_async(s, {"x": jnp.full((8,), s)})
    mgr.wait()
    assert mgr.latest_step() == 2
    # no stray temp files (atomic os.replace)
    assert not list(tmp_path.glob("*.tmp.npz"))
    _, tree = mgr.restore(2)
    assert float(tree["x"][0]) == 2.0


def test_checkpoint_elastic_restore_resharding(tmp_path):
    """A checkpoint written under one layout restores onto another sharding
    (single-device here; the API contract is sharding-pytree-driven)."""
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, tree)
    from jax.sharding import SingleDeviceSharding
    sh = {"w": SingleDeviceSharding(jax.devices()[0])}
    _, restored = mgr.restore(1, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup=3)
    for step in range(10):
        assert not mon.record(step, 0.1)
    assert mon.record(10, 0.5)           # 5× the mean → flagged
    assert not mon.record(11, 0.1)       # baseline not poisoned
    assert mon.straggler_fraction == pytest.approx(1 / 12)


def test_retry_step_restores_and_replays(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"params": {"w": jnp.ones(2)}, "opt": {"s": jnp.zeros(1)}})
    calls = {"n": 0}

    def flaky(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("hard fault")
        return params, opt_state, {"loss": jnp.float32(0.0)}

    wrapped = retry_step(flaky, mgr, max_retries=2)
    out = wrapped({"w": jnp.zeros(2)}, {"s": jnp.zeros(1)}, None, step=5)
    assert calls["n"] == 2
    assert out[2]["loss"] == 0.0


def test_heartbeat_writes(tmp_path):
    hb = Heartbeat(tmp_path / "hb")
    hb.beat(42)
    assert (tmp_path / "hb").read_text().startswith("42 ")


# ------------------------------ compression ----------------------------------
def test_int8_quantization_error_bound():
    g = jax.random.normal(KEY, (1000,), jnp.float32) * 3.0
    q, scale, shape = quantize_int8(g, block=256)
    rec = dequantize_int8(q, scale, shape)
    # per-block max-abs scaling: error ≤ scale/2 per element
    err = np.abs(np.asarray(rec - g))
    bound = np.repeat(np.asarray(scale)[:, 0], 256)[:1000] * 0.5 + 1e-7
    assert (err <= bound).all()


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated compressed sum converges to the
    true sum (EF-SGD property) — without it, bias persists."""
    g = jax.random.normal(KEY, (512,), jnp.float32) * 0.01
    tree = {"g": g}
    err = None
    total = jnp.zeros_like(g)
    for _ in range(20):
        comp, err = compress_tree(tree, err)
        total = total + decompress_tree(comp)["g"]
    np.testing.assert_allclose(np.asarray(total / 20), np.asarray(g),
                               atol=2e-4)


def test_compression_ratio():
    g = jnp.ones((1024,), jnp.float32)
    q, scale, _ = quantize_int8(g, block=256)
    raw = g.size * 4
    comp = q.size * 1 + scale.size * 4
    assert comp < raw / 3


# ------------------------------ data pipeline ---------------------------------
def test_synthetic_tokens_deterministic():
    a = next(iter(SyntheticTokens(vocab=100, batch=2, seq=8, seed=3)))
    b = next(iter(SyntheticTokens(vocab=100, batch=2, seq=8, seed=3)))
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert a["labels"].shape == (2, 8)


def test_memmap_tokens_rank_sharding_and_resume(tmp_path):
    path = tmp_path / "corpus.bin"
    MemmapTokens.write_corpus(path, n_tokens=100_000, vocab=1000)
    r0 = MemmapTokens(path, batch=2, seq=16, rank=0, world=2)
    r1 = MemmapTokens(path, batch=2, seq=16, rank=1, world=2)
    b0, b1 = next(r0), next(r1)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))
    # deterministic resume: a fresh reader starting at step 1 sees the same
    # batch as the original reader's second step
    b0_next = next(r0)
    fresh = MemmapTokens(path, batch=2, seq=16, rank=0, world=2,
                         start_step=1)
    np.testing.assert_array_equal(np.asarray(next(fresh)["tokens"]),
                                  np.asarray(b0_next["tokens"]))
    # next-token alignment
    np.testing.assert_array_equal(np.asarray(b0["tokens"][:, 1:]),
                                  np.asarray(b0["labels"][:, :-1]))


def test_global_norm():
    tree = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(tree)) == pytest.approx(np.sqrt(3 + 16))

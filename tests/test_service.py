"""DSE service tests: warm-session engine lifecycle, the daemon's
multi-client contracts (bit-identical winners, shared cells priced
exactly once, per-client budgets, fair streaming), and the failure
edges the daemon must survive (client disconnect mid-stream, malformed
requests, garbage frames).

The service engine here runs ``parallel=False`` — the warm *pool* path
is covered by the warm-session engine tests above plus the bench/CI
smoke legs; the scheduler/protocol contracts are transport-independent
and a serial engine keeps these tests fast and robust on 1-CPU runners.
"""
from __future__ import annotations

import os
import pickle
import socket
import struct
import threading
import time

import pytest

from repro.core.dse_engine import DSEEngine
from repro.core.memo_store import diff_stats, recv_msg, send_msg
from repro.service import DSEClient, DSEService, ServiceError
from repro.service.protocol import RequestError, parse_query, resolve_query
from repro.workloads.scenarios import get_scenario

SCENARIO = "llm"


def _mp_context():
    return os.environ.get("DFMODEL_TEST_MP_CONTEXT") or None


def _reference_items():
    """Per-grid-index reference points from a cold serial engine."""
    sc = get_scenario(SCENARIO, smoke=True)
    eng = DSEEngine(parallel=False)
    return {it.index: it.point
            for it in eng.sweep_cells_iter(sc.work_fn, sc.spec.grid(),
                                           sc.spec)}


def _grid():
    return get_scenario(SCENARIO, smoke=True).spec.grid()


# --- warm-session engine lifecycle ------------------------------------------
def test_warm_session_sweeps_bit_identical_and_reentrant():
    sc = get_scenario(SCENARIO, smoke=True)
    ref = [p.row() for p in DSEEngine(parallel=False).sweep(sc.work_fn,
                                                            sc.spec)]
    kwargs = {}
    if _mp_context():
        kwargs["mp_context"] = _mp_context()
    with DSEEngine(max_workers=2, shared_cache=True, **kwargs) as eng:
        assert eng.session_active
        a = [p.row() for p in eng.sweep(sc.work_fn, sc.spec)]
        b = [p.row() for p in eng.sweep(sc.work_fn, sc.spec)]
        assert a == ref and b == ref
        # the session store survived both sweeps (stats snapshotted, not
        # torn down) — and a cells subset streams through the same pool
        items = list(eng.sweep_cells_iter(sc.work_fn, sc.spec.grid()[:5],
                                          sc.spec))
        assert sorted(i.index for i in items) == list(range(5))
    assert not eng.session_active
    # post-shutdown the engine still works in per-sweep mode
    c = [p.row() for p in eng.sweep(sc.work_fn, sc.spec)]
    assert c == ref


def test_warm_session_start_is_idempotent_and_serial_engines_session():
    eng = DSEEngine(parallel=False)
    try:
        assert eng.start() is eng and eng.start() is eng
        assert eng.session_active and eng._session_pool is None
    finally:
        eng.shutdown()
        eng.shutdown()  # idempotent


def test_diff_stats_reports_request_deltas():
    before = {"backend": "mmap", "hits": 2, "misses": 5, "inserts": 5,
              "dropped": 0, "entries": 5,
              "by_space": {"plan": {"hits": 2, "misses": 5, "inserts": 5,
                                    "dropped": 0}}}
    after = {"backend": "mmap", "hits": 9, "misses": 6, "inserts": 6,
             "dropped": 0, "entries": 6,
             "by_space": {"plan": {"hits": 9, "misses": 6, "inserts": 6,
                                   "dropped": 0}}}
    delta = diff_stats(before, after)
    assert delta["hits"] == 7 and delta["entries"] == 1
    assert delta["by_space"]["plan"]["hits"] == 7
    assert diff_stats(None, after) is after
    assert diff_stats(before, None) is None


# --- protocol validation ----------------------------------------------------
def test_parse_query_rejects_malformed_requests():
    with pytest.raises(RequestError) as exc:
        parse_query({"op": "query", "mode": "warp"})
    assert exc.value.code == "bad-mode"
    with pytest.raises(RequestError) as exc:
        parse_query({"op": "query", "budget": 0})
    assert exc.value.code == "bad-budget"
    with pytest.raises(RequestError) as exc:
        parse_query({"op": "query", "cells": [1, 1]})
    assert exc.value.code == "bad-cells"
    with pytest.raises(RequestError) as exc:
        parse_query({"op": "query", "frobnicate": 1})
    assert exc.value.code == "bad-field"
    with pytest.raises(RequestError) as exc:
        resolve_query(parse_query({"op": "query", "scenario": "nope"}))
    assert exc.value.code == "unknown-scenario"
    with pytest.raises(RequestError) as exc:
        resolve_query(parse_query({"op": "query", "cells": [10 ** 6]}))
    assert exc.value.code == "bad-cells"
    with pytest.raises(RequestError) as exc:
        resolve_query(parse_query({"op": "query", "mode": "search",
                                   "policy": "psychic"}))
    assert exc.value.code == "unknown-policy"


# --- the shared daemon the remaining tests multiplex ------------------------
@pytest.fixture(scope="module")
def service():
    with DSEService(parallel=False, batch_cells=4) as svc:
        yield svc


def test_two_concurrent_clients_winners_bit_identical_and_priced_once():
    """The acceptance criterion, in-process: overlapping concurrent
    grids → every shared cell priced exactly once, every row (and hence
    the winner) bit-identical to a direct DSEEngine sweep."""
    ref = _reference_items()
    n = len(_grid())
    a_cells = list(range(0, 2 * n // 3))
    b_cells = list(range(n // 3, n))
    overlap = set(a_cells) & set(b_cells)
    results: dict = {}

    # a fresh service: this test asserts exact priced-once accounting
    with DSEService(parallel=False, batch_cells=4) as svc:
        def run(name, cells):
            with DSEClient(svc.path) as cli:
                results[name] = cli.sweep(scenario=SCENARIO, smoke=True,
                                          cells=cells, client=name)

        threads = [threading.Thread(target=run, args=("A", a_cells)),
                   threading.Thread(target=run, args=("B", b_cells))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        with DSEClient(svc.path) as cli:
            sched = cli.stats()["scheduler"]

    assert set(results) == {"A", "B"}
    # exactly-once pricing: the union of both grids, nothing more
    assert sched["cells_priced"] == n
    assert sched["dedup_hits"] >= len(overlap)
    assert (results["A"].summary["dedup_hits"]
            + results["B"].summary["dedup_hits"]) == sched["dedup_hits"]
    for name, cells in (("A", a_cells), ("B", b_cells)):
        rep = results[name]
        assert sorted(rep.indices) == cells
        for idx, pt in zip(rep.indices, rep.points):
            ref_pt = ref[idx]
            assert (pt is None) == (ref_pt is None)
            if pt is not None:
                assert pt.row() == ref_pt.row()
        # the winner is the lexicographic argmin over the client's cells
        want = min(((pt is None or not pt.plan.feasible),
                    float("inf") if pt is None else pt.plan.iter_time, idx)
                   for idx, pt in ((i, ref[i]) for i in cells))
        got = rep.summary["winner"]
        assert (got["index"], got["feasible"], got["iter_time"]) == (
            want[2], not want[0], want[1])


def test_full_sweep_matches_direct_engine(service):
    sc = get_scenario(SCENARIO, smoke=True)
    direct = [p.row() for p in DSEEngine(parallel=False).sweep(sc.work_fn,
                                                               sc.spec)]
    with DSEClient(service.path) as cli:
        rep = cli.sweep(scenario=SCENARIO, smoke=True)
    assert rep.rows() == direct
    assert len(rep.frontier()) >= 1


def test_repeat_request_served_from_memo(service):
    with DSEClient(service.path) as cli:
        first = cli.sweep(scenario=SCENARIO, smoke=True)
        before = cli.stats()["scheduler"]["cells_priced"]
        again = cli.sweep(scenario=SCENARIO, smoke=True)
        after = cli.stats()["scheduler"]["cells_priced"]
    assert after == before  # warm request priced nothing new
    assert again.summary["dedup_hits"] == again.summary["rows"]
    assert again.rows() == first.rows()


def test_client_disconnect_mid_stream_leaves_daemon_serviceable(service):
    # hand-rolled client: send a sweep query, read ONE message, vanish
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(service.path)
    send_msg(sock, {"op": "query", "mode": "sweep", "scenario": SCENARIO,
                    "smoke": True, "client": "rude"})
    assert recv_msg(sock) is not None  # one streamed message arrived
    sock.close()  # mid-stream disconnect
    # the daemon (and its warm engine) must keep serving everyone else
    with DSEClient(service.path) as cli:
        rep = cli.sweep(scenario=SCENARIO, smoke=True)
        assert rep.summary["rows"] == len(_grid())
        assert cli.ping()["kind"] == "pong"


def test_malformed_request_structured_error_daemon_survives(service):
    with DSEClient(service.path) as cli:
        with pytest.raises(ServiceError) as exc:
            cli.sweep(scenario="not-a-scenario")
        assert exc.value.code == "unknown-scenario"
        # the same connection keeps working after the error
        assert cli.ping()["kind"] == "pong"
        with pytest.raises(ServiceError) as exc:
            list(cli.query_iter(mode="warp"))
        assert exc.value.code == "bad-mode"
        with pytest.raises(ServiceError) as exc:
            cli._roundtrip({"op": "frobnicate"})
        assert exc.value.code == "bad-op"


def test_garbage_frame_gets_error_reply_daemon_survives(service):
    # raw bytes that length-prefix fine but do not unpickle
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(service.path)
    payload = b"this is not a pickle"
    sock.sendall(struct.pack("<Q", len(payload)) + payload)
    reply = recv_msg(sock)
    assert reply is not None and reply["kind"] == "error"
    assert reply["code"] == "bad-frame"
    sock.close()
    with DSEClient(service.path) as cli:  # daemon alive
        assert cli.ping()["kind"] == "pong"


def test_budget_bounds_fresh_prices_and_reports_skips():
    n = len(_grid())
    budget = 3
    with DSEService(parallel=False, batch_cells=4) as svc:
        with DSEClient(svc.path) as cli:
            rep = cli.sweep(scenario=SCENARIO, smoke=True, budget=budget)
            sched = cli.stats()["scheduler"]
    assert rep.summary["budget_used"] == budget
    assert sched["cells_priced"] == budget
    assert rep.summary["skipped"] == n - budget
    assert rep.summary["rows"] == budget


def test_search_mode_certified_winner_and_memo_harvest(service):
    with DSEClient(service.path) as cli:
        before = cli.stats()["scheduler"]["memo_cells"]
        rep = cli.search(scenario=SCENARIO, smoke=True, policy="halving",
                         budget=6)
        after = cli.stats()["scheduler"]["memo_cells"]
    assert rep.summary["certified"] is True
    assert rep.summary["best_index"] == rep.summary["oracle_index"]
    assert rep.summary["evals_used"] <= 6
    assert rep.winner is not None and rep.winner["feasible"]
    assert after >= before  # observations seeded the shared memo
    # the certified winner matches the direct exhaustive argmin
    ref = _reference_items()
    want = min(((pt is None or not pt.plan.feasible),
                float("inf") if pt is None else pt.plan.iter_time, idx)
               for idx, pt in ref.items())
    assert rep.summary["best_index"] == want[2]


def test_stats_reports_engine_and_scheduler(service):
    with DSEClient(service.path) as cli:
        st = cli.stats()
    assert st["kind"] == "stats"
    assert st["engine"]["session_active"] is True
    assert st["scheduler"]["requests"] >= 1
    assert st["uptime_s"] >= 0


def test_shutdown_op_stops_daemon():
    svc = DSEService(parallel=False)
    svc.start()
    with DSEClient(svc.path) as cli:
        cli.shutdown_server()
    assert svc.wait(timeout=10)
    svc.close()
    with pytest.raises((FileNotFoundError, ConnectionRefusedError)):
        DSEClient(svc.path, connect_timeout=0.2)

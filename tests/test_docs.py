"""Docs freshness gates.

Three invariants keep `docs/` from rotting:

* the env-var doctests in `docs/ENV_VARS.md` execute against the real
  parsers (`default_backend` / `resolve_backend` / `default_prune` /
  `resolve_prune` / `drift_band` / `default_rank` / `resolve_rank` /
  `rank_keep_frac`), and the learned rank-stage doctests in
  `docs/LEARNED.md` execute against the real keep rule, so documented
  spellings, defaults and error messages cannot drift from the code;
* every dotted `repro.*` name any doc mentions (`ARCHITECTURE.md`,
  `ENV_VARS.md`, `LEARNED.md`) resolves to a real module (or an
  attribute of one) — renaming a module without updating the
  architecture map fails CI;
* the `DFMODEL_*` catalogue in `docs/ENV_VARS.md` matches exactly the
  knob names greppable under `src/`, `tools/` and `benchmarks/` — a new
  knob must be documented, a documented knob must still exist.
"""
from __future__ import annotations

import doctest
import importlib
import importlib.util
import os
import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parents[1]
DOCS = REPO / "docs"

ENV_VAR_RE = re.compile(r"DFMODEL_[A-Z0-9_]+")
MODULE_RE = re.compile(r"\brepro(?:\.\w+)+")

#: env vars the ENV_VARS.md doctests mutate (snapshot/restore around them)
_DOCTEST_VARS = ("DFMODEL_PRICING_BACKEND", "DFMODEL_PRUNE",
                 "DFMODEL_DRIFT_BAND", "DFMODEL_RANK",
                 "DFMODEL_RANK_KEEP_FRAC", "DFMODEL_VALIDATION_REPEATS",
                 "DFMODEL_VALIDATION_WARMUP", "DFMODEL_VALIDATION_BAND",
                 "DFMODEL_VALIDATION_BYTES_FACTOR",
                 "DFMODEL_VALIDATION_WALL_BAND")


def test_env_vars_doctests_execute():
    saved = {k: os.environ.get(k) for k in _DOCTEST_VARS}
    try:
        for k in _DOCTEST_VARS:
            os.environ.pop(k, None)
        result = doctest.testfile(str(DOCS / "ENV_VARS.md"),
                                  module_relative=False, verbose=False)
        assert result.attempted >= 15, "doctest examples went missing"
        assert result.failed == 0, (
            f"{result.failed} of {result.attempted} ENV_VARS.md doctests "
            f"failed (see captured stdout)")
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _resolves(name: str) -> bool:
    """True if ``name`` is an importable module, or a trailing-attribute
    path on one (``repro.core.pricing.default_backend``)."""
    parts = name.split(".")
    for cut in range(len(parts), 1, -1):
        modname = ".".join(parts[:cut])
        try:
            spec = importlib.util.find_spec(modname)
        except (ModuleNotFoundError, ValueError):
            spec = None
        if spec is None:
            continue
        obj = importlib.import_module(modname)
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def test_architecture_names_are_fresh():
    text = (DOCS / "ARCHITECTURE.md").read_text()
    names = sorted(set(MODULE_RE.findall(text)))
    assert len(names) >= 20, "the architecture map lost its module names"
    missing = [n for n in names if not _resolves(n)]
    assert not missing, (
        f"ARCHITECTURE.md names things that no longer exist: {missing}")


def test_env_vars_doc_names_are_fresh():
    text = (DOCS / "ENV_VARS.md").read_text()
    missing = [n for n in sorted(set(MODULE_RE.findall(text)))
               if not _resolves(n)]
    assert not missing, (
        f"ENV_VARS.md names things that no longer exist: {missing}")


def test_learned_doc_names_are_fresh():
    text = (DOCS / "LEARNED.md").read_text()
    names = sorted(set(MODULE_RE.findall(text)))
    assert len(names) >= 8, "LEARNED.md lost its module names"
    missing = [n for n in names if not _resolves(n)]
    assert not missing, (
        f"LEARNED.md names things that no longer exist: {missing}")


def test_learned_doctests_execute():
    result = doctest.testfile(str(DOCS / "LEARNED.md"),
                              module_relative=False, verbose=False)
    assert result.attempted >= 5, "LEARNED.md doctest examples went missing"
    assert result.failed == 0, (
        f"{result.failed} of {result.attempted} LEARNED.md doctests "
        f"failed (see captured stdout)")


def _tree_env_vars() -> set[str]:
    found: set[str] = set()
    for sub in ("src", "tools", "benchmarks"):
        for path in (REPO / sub).rglob("*"):
            if path.is_file() and path.suffix in (".py", ".sh"):
                found |= set(ENV_VAR_RE.findall(path.read_text()))
    return found


def test_env_var_catalogue_in_sync():
    doc = set(ENV_VAR_RE.findall((DOCS / "ENV_VARS.md").read_text()))
    tree = _tree_env_vars()
    undocumented = sorted(tree - doc)
    stale = sorted(doc - tree)
    assert not undocumented, (
        f"DFMODEL_* knobs missing from docs/ENV_VARS.md: {undocumented}")
    assert not stale, (
        f"docs/ENV_VARS.md documents knobs nothing reads: {stale}")

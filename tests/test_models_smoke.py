"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; prefill/decode consistency for cached archs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill, synth_batch)
from repro.models.transformer import _memory_from_batch
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    batch = synth_batch(cfg, batch=2, seq=64)
    memory = _memory_from_batch(cfg, params, batch)
    logits = jax.jit(lambda p, t: forward(cfg, p, t, memory=memory))(
        params, batch["tokens"])
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_reduces_loss_direction(arch):
    """One AdamW step on a fixed batch must keep loss finite and (after a
    couple of steps on the same batch) reduce it — overfit sanity."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    batch = synth_batch(cfg, batch=2, seq=32)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.0)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(lambda pp: loss_fn(cfg, pp, batch))(p)
        p, o = adamw_update(p, g, o, ocfg)
        return p, o, loss

    losses = []
    for _ in range(4):
        params, opt, loss = step(params, opt)
        assert bool(jnp.isfinite(loss)), arch
        losses.append(float(loss))
    assert losses[-1] < losses[0], (arch, losses)


DECODE_ARCHS = ["olmo_1b", "mistral_nemo_12b", "mamba2_130m",
                "jamba_v01_52b", "olmoe_1b_7b", "seamless_m4t_medium",
                "llama32_vision_11b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode_step(t) after prefill([t0..t_{n-1}]) must reproduce the full
    forward logits at position n (teacher-forcing equivalence)."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    b, s = 2, 16
    batch = synth_batch(cfg, batch=b, seq=s + 1)
    toks = batch["tokens"]
    memory = _memory_from_batch(cfg, params, batch)

    full = forward(cfg, params, toks, memory=memory, remat=False)
    logits_pre, cache = prefill(cfg, params, toks[:, :s], memory=memory)
    # grow the cache to hold one more token
    grown = init_cache(cfg, b, s + 1)
    if "k" in cache:
        grown["k"] = grown["k"].at[:, :, :, :s].set(cache["k"])
        grown["v"] = grown["v"].at[:, :, :, :s].set(cache["v"])
    if "ssm" in cache:
        grown["ssm"] = cache["ssm"]
        grown["conv"] = cache["conv"]
    step_logits, _ = decode_step(cfg, params, grown, toks[:, s],
                                 jnp.int32(s), memory=memory)

    ref = full[:, s].astype(jnp.float32)
    got = step_logits.astype(jnp.float32)
    # bf16 accumulation differences across code paths
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0.15, atol=0.15)
    # and the argmax token agrees for nearly every row
    agree = (got.argmax(-1) == ref.argmax(-1)).mean()
    assert agree >= 0.5, (arch, float(agree))


def test_vlm_uses_image_memory():
    cfg = get_config("llama32_vision_11b", smoke=True)
    params = init_params(cfg, KEY)
    batch = synth_batch(cfg, batch=2, seq=32)
    l_with = forward(cfg, params, batch["tokens"],
                     memory=batch["image_embeds"])
    l_without = forward(cfg, params, batch["tokens"],
                        memory=jnp.zeros_like(batch["image_embeds"]))
    assert not bool(jnp.allclose(l_with, l_without))


def test_encdec_encoder_affects_decoder():
    cfg = get_config("seamless_m4t_medium", smoke=True)
    params = init_params(cfg, KEY)
    batch = synth_batch(cfg, batch=2, seq=32)
    m1 = _memory_from_batch(cfg, params, batch)
    b2 = dict(batch, audio_frames=batch["audio_frames"] * 2.0)
    m2 = _memory_from_batch(cfg, params, b2)
    l1 = forward(cfg, params, batch["tokens"], memory=m1)
    l2 = forward(cfg, params, batch["tokens"], memory=m2)
    assert not bool(jnp.allclose(l1, l2))


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor ≥ 1 and balanced-ish routing, the capacity MoE
    output stays close to the exact dropless computation on average."""
    from repro.models import layers as L
    cfg = get_config("olmoe_1b_7b", smoke=True)
    p = L.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model),
                          jnp.float32)
    y_cap = L.moe(p, x, cfg, capacity_factor=8.0)   # large cap: no drops
    y_dense = L.moe_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dense),
                               rtol=2e-2, atol=2e-2)


def test_param_count_formula_close_to_actual():
    from repro.models import param_count
    for arch in ("olmo_1b", "olmoe_1b_7b", "mamba2_130m"):
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, KEY)
        actual = param_count(params)
        est = cfg.param_count()
        assert est == pytest.approx(actual, rel=0.15), (arch, est, actual)

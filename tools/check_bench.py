"""CI bench-regression gate for the DSE engine.

Runs the smoke `speedup_report` (the same measurement `benchmarks.run
--smoke` takes) into a scratch file and compares it against the committed
`BENCH_dse.json` baseline:

* **row identity** — every evaluation path must still produce bit-identical
  `DesignPoint.row()` lists (`rows_identical` true in the fresh report);
* **throughput** — per-path points-per-second may not fall below
  `baseline / $DFMODEL_BENCH_SLOWDOWN` (default 4.0: CI machines are
  noisy and heterogeneous; the gate catches order-of-magnitude rot, not
  scheduler jitter);
* **phased speedup** — the warm-cache phased-vs-per-point ratio (the
  engine's headline number) must stay ≥ $DFMODEL_BENCH_MIN_SPEEDUP
  (default 0.8 — the committed baseline is ~1.9×);
* **cache hit-rate** — the memo-cache hit rate may not drop more than
  $DFMODEL_BENCH_HIT_DROP (default 0.02 absolute) below the baseline.

Exit 1 on any regression. `--update` rewrites the committed baseline with
the fresh numbers instead (run it on the machine that owns the baseline
after a deliberate perf change).

  PYTHONPATH=src python tools/check_bench.py [--update] [--baseline PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))          # benchmarks package
sys.path.insert(0, str(REPO / "src"))  # repro package
BASELINE = REPO / "BENCH_dse.json"


def _fresh_report() -> dict:
    from benchmarks.bench_dse import speedup_report

    with tempfile.TemporaryDirectory() as tmp:
        path = pathlib.Path(tmp) / "BENCH_dse.json"
        speedup_report("llm", smoke=True, json_path=path)
        return json.loads(path.read_text())


def _hit_rate(report: dict) -> float:
    cache = report.get("cache", {})
    total = cache.get("hits", 0) + cache.get("misses", 0)
    return cache.get("hits", 0) / total if total else 0.0


def compare(fresh: dict, base: dict,
            slowdown: float, min_speedup: float,
            hit_drop: float) -> list[str]:
    """Return a list of human-readable regression messages (empty = pass)."""
    problems: list[str] = []
    if not fresh.get("rows_identical", False):
        problems.append("rows_identical is False: the evaluation paths "
                        "no longer agree bit-for-bit")
    for path, vals in base.get("paths", {}).items():
        got = fresh.get("paths", {}).get(path)
        if got is None:
            problems.append(f"path {path!r} missing from the fresh report")
            continue
        floor = vals["points_per_s"] / slowdown
        if got["points_per_s"] < floor:
            problems.append(
                f"{path}: {got['points_per_s']:.1f} points/s < "
                f"{floor:.1f} (baseline {vals['points_per_s']:.1f} "
                f"/ slowdown limit {slowdown:g})")
    ratio = fresh.get("speedup_phased_vs_perpoint", 0.0)
    if ratio < min_speedup:
        problems.append(
            f"warm phased-vs-perpoint speedup {ratio:.2f} < {min_speedup:g} "
            f"(baseline {base.get('speedup_phased_vs_perpoint', 0.0):.2f})")
    fresh_hr, base_hr = _hit_rate(fresh), _hit_rate(base)
    if fresh_hr < base_hr - hit_drop:
        problems.append(
            f"cache hit-rate {fresh_hr:.3f} < baseline {base_hr:.3f} "
            f"- {hit_drop:g}")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", type=pathlib.Path, default=BASELINE,
                    help=f"baseline JSON (default {BASELINE})")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline with fresh numbers")
    args = ap.parse_args()

    slowdown = float(os.environ.get("DFMODEL_BENCH_SLOWDOWN", "4.0"))
    min_speedup = float(os.environ.get("DFMODEL_BENCH_MIN_SPEEDUP", "0.8"))
    hit_drop = float(os.environ.get("DFMODEL_BENCH_HIT_DROP", "0.02"))

    fresh = _fresh_report()
    if args.update:
        args.baseline.write_text(json.dumps(fresh, indent=2) + "\n")
        print(f"bench baseline updated: {args.baseline} "
              f"(warm phased speedup "
              f"{fresh['speedup_phased_vs_perpoint']:.2f}x)")
        return 0
    if not args.baseline.exists():
        print(f"bench gate: no baseline at {args.baseline}; "
              f"run with --update to create one", file=sys.stderr)
        return 1
    base = json.loads(args.baseline.read_text())
    problems = compare(fresh, base, slowdown, min_speedup, hit_drop)
    for path, vals in fresh.get("paths", {}).items():
        print(f"  {path:20s} {vals['points_per_s']:10.1f} points/s "
              f"(baseline "
              f"{base.get('paths', {}).get(path, {}).get('points_per_s', 0.0):10.1f})")
    if problems:
        print("bench gate: REGRESSION", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"bench gate: PASS (rows identical, warm phased speedup "
          f"{fresh['speedup_phased_vs_perpoint']:.2f}x, hit rate "
          f"{_hit_rate(fresh):.3f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

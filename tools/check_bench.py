"""CI bench-regression gate for the DSE engine.

Runs the smoke `speedup_report` (the same measurement `benchmarks.run
--smoke` takes) into a scratch file and compares it against the committed
`BENCH_dse.json` baseline:

* **row identity** — every evaluation path must still produce bit-identical
  `DesignPoint.row()` lists (`rows_identical` true in the fresh report);
* **throughput** — per-path points-per-second may not fall below
  `baseline / $DFMODEL_BENCH_SLOWDOWN` (default 4.0: CI machines are
  noisy and heterogeneous; the gate catches order-of-magnitude rot, not
  scheduler jitter);
* **phased speedup** — the warm-cache phased-vs-per-point ratio (the
  engine's headline number) must stay ≥ $DFMODEL_BENCH_MIN_SPEEDUP
  (default 0.8 — the committed baseline is ~1.9×);
* **cache hit-rate** — the memo-cache hit rate may not drop more than
  $DFMODEL_BENCH_HIT_DROP (default 0.02 absolute) below the baseline;
* **cross-process sharing** — the `cold_parallel_shared` path (the
  engine with the shared memo store of `repro.core.memo_store`) must be
  present — its row identity and points/sec floor ride the generic
  checks above — and its aggregated cross-worker hit count must be
  ≥ $DFMODEL_BENCH_SHARED_MIN_HITS (default 1: workers provably reused
  each other's solves), with the shared hit-rate above the absolute
  floor $DFMODEL_BENCH_SHARED_MIN_RATE (default 0.002 — the rate is
  pool-scheduling-dependent, so the floor is deliberately loose);
* **budgeted search** — the report's `search` block must show every
  shipped policy certified on the smoke grid (winner identical to the
  exhaustive argmin, evaluations within budget) and the dense-grid
  successive-halving run certified while spending
  ≤ $DFMODEL_BENCH_SEARCH_MAX_FRAC of exhaustive evaluations (default
  0.2 — the paper-scale sweep replaced by a budgeted search) at no less
  than `baseline / $DFMODEL_BENCH_SLOWDOWN` search points/sec;
* **compiled f32 pricing** — the report's `compiled` block (present
  whenever jax is importable, like the other jax legs) must show
  `winners_identical` true across every smoke scenario AND the dense
  grid (the drift-budget contract: banded f32 selection + exact f64
  re-pricing provably reproduces the scalar reference), the grid sized
  at ≥ $DFMODEL_BENCH_GRID_MIN_CELLS cells (default 100000), the
  exact-re-price fraction at ≤ $DFMODEL_BENCH_REPRICED_FRAC (default
  0.5 — the band is supposed to *bound* the exact work, not hide it),
  and the grid cells/sec + streamed kernel rows/sec above their
  baseline-over-slowdown floors;
* **DSE service** — the report's `service` block (the warm daemon of
  `repro.service`) must show the warm full-grid repeat bit-identical to
  a direct `DSEEngine.sweep` (`winners_identical`), the warm request at
  least $DFMODEL_BENCH_SERVICE_MIN_SPEEDUP× faster than the cold
  daemon-start-plus-first-sweep phase (default 2.0 — warm requests are
  answered from the shared memo, so this certifies the daemon actually
  keeps state warm), the cold concurrent clients sharing at least
  $DFMODEL_BENCH_SERVICE_MIN_DEDUP cross-client dedup hits (default 1:
  overlapping grids provably price shared cells once), and the warm
  streamed rows/sec above its baseline-over-slowdown floor;
* **candidate pruning** — the report's `prune` block must show the
  pruning stage enabled with `winners_identical` true (the prune-on
  engine's DesignPoint rows reproduce the prune-off engine's
  bit-for-bit), strictly fewer candidate rows priced than enumerated,
  and the prune-on engine's points/sec no lower than the prune-off
  engine's divided by $DFMODEL_BENCH_PRUNE_SLACK (default 1.5 — the
  smoke grid is tiny, so per-run scheduler noise dominates; the gate
  certifies "pruning does not slow the sweep down", not a speedup);
* **learned rank stage** — the report's `learned` block must show the
  calibrated ranker enabled with `winners_identical` true (rank-on
  DesignPoint rows reproduce rank-off bit-for-bit on every smoke
  scenario), the dense-grid pricing-volume shrink over dominance-only
  (`shrink_vs_dominance`) at least $DFMODEL_BENCH_RANK_SHRINK (default
  3.0 — the rank stage prices ≤ 1/3 of the dominance survivors), and
  the model's achieved harvest recall at least its own stated
  `recall_target` (the calibration must deliver the recall it claims).

Exit 1 on any regression. `--update` rewrites the committed baseline with
the fresh numbers instead (run it on the machine that owns the baseline
after a deliberate perf change); it first runs the tier-1 test suite and
REFUSES to touch the baseline while any test is red — a baseline
captured on a broken tree would launder the breakage into CI. `--fresh-out PATH` (or
$DFMODEL_BENCH_FRESH_OUT) additionally keeps the freshly measured report
at PATH — CI uploads it as an artifact when the gate fails, so a
regression can be diffed against the committed baseline offline.

  PYTHONPATH=src python tools/check_bench.py [--update] [--baseline PATH]
                                             [--fresh-out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))          # benchmarks package
sys.path.insert(0, str(REPO / "src"))  # repro package
BASELINE = REPO / "BENCH_dse.json"


def _fresh_report(fresh_out: pathlib.Path | None) -> dict:
    from benchmarks.bench_dse import speedup_report

    if fresh_out is not None:
        speedup_report("llm", smoke=True, json_path=fresh_out)
        return json.loads(fresh_out.read_text())
    with tempfile.TemporaryDirectory() as tmp:
        path = pathlib.Path(tmp) / "BENCH_dse.json"
        speedup_report("llm", smoke=True, json_path=path)
        return json.loads(path.read_text())


def _hit_rate(report: dict) -> float:
    cache = report.get("cache", {})
    total = cache.get("hits", 0) + cache.get("misses", 0)
    return cache.get("hits", 0) / total if total else 0.0


def _shared_hit_rate(report: dict) -> float:
    shared = report.get("shared_cache") or {}
    total = shared.get("hits", 0) + shared.get("misses", 0)
    return shared.get("hits", 0) / total if total else 0.0


def _check_search_entry(problems: list[str], label: str, entry: dict,
                        base_entry: dict, slowdown: float) -> None:
    """Certification + budget accounting + throughput floor for one
    search entry (a smoke policy or the dense-grid run)."""
    if not entry.get("certified", False):
        problems.append(f"{label}: certification did not run")
    if not entry.get("winner_identical", False):
        problems.append(
            f"{label}: winner {entry.get('best_index')} != exhaustive "
            f"argmin {entry.get('oracle_index')}")
    if entry.get("evals_used", 0) > entry.get("budget", 0):
        problems.append(
            f"{label}: {entry.get('evals_used')} evaluations exceed the "
            f"budget {entry.get('budget')}")
    floor = base_entry.get("points_per_s", 0.0) / slowdown
    if entry.get("points_per_s", 0.0) < floor:
        problems.append(
            f"{label}: {entry.get('points_per_s', 0.0):.1f} search "
            f"points/s < {floor:.1f} (baseline "
            f"{base_entry.get('points_per_s', 0.0):.1f} / slowdown "
            f"limit {slowdown:g})")


def _check_compiled(problems: list[str], fresh: dict, base: dict,
                    slowdown: float, grid_min_cells: int,
                    repriced_max_frac: float) -> None:
    """The drift-budget contract gate for the `compiled` report block."""
    entry = fresh.get("compiled")
    base_entry = base.get("compiled") or {}
    if not entry:
        problems.append("compiled block missing: the f32 pricing "
                        "benchmark did not run")
        return
    if not entry.get("available", False):
        # a jax-less interpreter can't run the backend at all — only a
        # regression if the committed baseline DID have it available
        if base_entry.get("available", False):
            problems.append("compiled.available is False but the baseline "
                            "ran the f32 backend: jax import regressed")
        return
    if not entry.get("winners_identical", False):
        bad = [name for name, e in (entry.get("smoke") or {}).items()
               if not e.get("winners_identical", False)]
        where = f" (smoke scenarios: {', '.join(bad)})" if bad else " (grid)"
        problems.append(f"compiled.winners_identical is False{where}: the "
                        f"drift-banded f32 selection no longer reproduces "
                        f"the f64 scalar reference")
    grid = entry.get("grid") or {}
    if grid.get("cells", 0) < grid_min_cells:
        problems.append(
            f"compiled grid certified only {grid.get('cells', 0)} cells "
            f"< floor {grid_min_cells}")
    frac = grid.get("repriced_frac", 1.0)
    if frac > repriced_max_frac:
        problems.append(
            f"compiled grid re-priced {frac:.3f} of candidate rows "
            f"exactly > ceiling {repriced_max_frac:g}: the drift band no "
            f"longer bounds the exact-pricing fallback")
    base_grid = base_entry.get("grid") or {}
    floor = base_grid.get("cells_per_s", 0.0) / slowdown
    if grid.get("cells_per_s", 0.0) < floor:
        problems.append(
            f"compiled grid {grid.get('cells_per_s', 0.0):.1f} cells/s < "
            f"{floor:.1f} (baseline {base_grid.get('cells_per_s', 0.0):.1f}"
            f" / slowdown limit {slowdown:g})")
    stream = entry.get("stream") or {}
    base_stream = base_entry.get("stream") or {}
    floor = base_stream.get("rows_per_s", 0.0) / slowdown
    if stream.get("rows_per_s", 0.0) < floor:
        problems.append(
            f"compiled stream {stream.get('rows_per_s', 0.0):.1f} rows/s "
            f"< {floor:.1f} (baseline "
            f"{base_stream.get('rows_per_s', 0.0):.1f} / slowdown limit "
            f"{slowdown:g})")


def _check_service(problems: list[str], fresh: dict, base: dict,
                   slowdown: float, min_speedup: float,
                   min_dedup: int) -> None:
    """The warm-daemon contract gate for the `service` report block."""
    entry = fresh.get("service")
    if not entry:
        problems.append("service block missing: the DSE service benchmark "
                        "did not run")
        return
    if not entry.get("winners_identical", False):
        problems.append("service.winners_identical is False: the warm "
                        "daemon's rows no longer reproduce a direct "
                        "DSEEngine.sweep bit-for-bit")
    speedup = entry.get("warm_speedup", 0.0)
    if speedup < min_speedup:
        problems.append(
            f"service warm-request speedup {speedup:.2f}x < floor "
            f"{min_speedup:g}x: the daemon no longer answers warm "
            f"requests from its shared memo")
    dedup = entry.get("dedup_hits", 0)
    if dedup < min_dedup:
        problems.append(
            f"service cross-client dedup hits {dedup} < {min_dedup}: "
            f"concurrent overlapping grids no longer share priced cells")
    base_entry = base.get("service") or {}
    floor = base_entry.get("rows_per_s", 0.0) / slowdown
    if entry.get("rows_per_s", 0.0) < floor:
        problems.append(
            f"service warm stream {entry.get('rows_per_s', 0.0):.1f} "
            f"rows/s < {floor:.1f} (baseline "
            f"{base_entry.get('rows_per_s', 0.0):.1f} / slowdown limit "
            f"{slowdown:g})")


def _check_learned(problems: list[str], fresh: dict,
                   rank_shrink: float) -> None:
    """The learned rank-stage gate for the `learned` report block."""
    entry = fresh.get("learned")
    if not entry:
        problems.append("learned block missing: the rank-stage benchmark "
                        "did not run")
        return
    if not entry.get("enabled", False):
        problems.append("learned.enabled is False: the harvest could not "
                        "train a ranker (staleness guard tripped on a "
                        "full smoke-sweep harvest)")
        return
    if not entry.get("winners_identical", False):
        bad = [name for name, e in (entry.get("scenarios") or {}).items()
               if not e.get("winners_identical", False)]
        problems.append(
            f"learned.winners_identical is False "
            f"(scenarios: {', '.join(bad) or '?'}): rank-on rows no "
            f"longer reproduce rank-off bit-for-bit")
    grid = entry.get("grid") or {}
    if not grid.get("winners_identical", False):
        problems.append("learned.grid.winners_identical is False: the "
                        "dense-grid reprice no longer certifies under "
                        "the rank stage")
    shrink = entry.get("shrink_vs_dominance", 0.0)
    if shrink < rank_shrink:
        problems.append(
            f"learned dense-grid shrink {shrink:.2f}x over dominance-only "
            f"< floor {rank_shrink:g}x ({grid.get('rank_survived', 0)} of "
            f"{grid.get('survived', 0)} dominance survivors priced)")
    model = entry.get("model") or {}
    recall = model.get("recall", 0.0)
    target = model.get("recall_target", 1.0)
    if recall < target:
        problems.append(
            f"learned model recall {recall:.3f} < its stated target "
            f"{target:g}: the keep-threshold calibration is broken")


def compare(fresh: dict, base: dict,
            slowdown: float, min_speedup: float,
            hit_drop: float, shared_min_hits: int = 1,
            shared_min_rate: float = 0.002,
            prune_slack: float = 1.5,
            search_max_frac: float = 0.2,
            grid_min_cells: int = 100_000,
            repriced_max_frac: float = 0.5,
            service_min_speedup: float = 2.0,
            service_min_dedup: int = 1,
            rank_shrink: float = 3.0) -> list[str]:
    """Return a list of human-readable regression messages (empty = pass)."""
    problems: list[str] = []
    if not fresh.get("rows_identical", False):
        problems.append("rows_identical is False: the evaluation paths "
                        "no longer agree bit-for-bit")
    for path, vals in base.get("paths", {}).items():
        got = fresh.get("paths", {}).get(path)
        if got is None:
            problems.append(f"path {path!r} missing from the fresh report")
            continue
        floor = vals["points_per_s"] / slowdown
        if got["points_per_s"] < floor:
            problems.append(
                f"{path}: {got['points_per_s']:.1f} points/s < "
                f"{floor:.1f} (baseline {vals['points_per_s']:.1f} "
                f"/ slowdown limit {slowdown:g})")
    ratio = fresh.get("speedup_phased_vs_perpoint", 0.0)
    if ratio < min_speedup:
        problems.append(
            f"warm phased-vs-perpoint speedup {ratio:.2f} < {min_speedup:g} "
            f"(baseline {base.get('speedup_phased_vs_perpoint', 0.0):.2f})")
    fresh_hr, base_hr = _hit_rate(fresh), _hit_rate(base)
    if fresh_hr < base_hr - hit_drop:
        problems.append(
            f"cache hit-rate {fresh_hr:.3f} < baseline {base_hr:.3f} "
            f"- {hit_drop:g}")
    # the cross-process shared-store row: the sweep must have run with the
    # shared memo store attached AND workers must actually have reused
    # each other's solves (row identity + throughput ride the generic
    # checks above once the row is in the baseline)
    if "cold_parallel_shared" not in fresh.get("paths", {}):
        problems.append("path 'cold_parallel_shared' missing: the shared "
                        "memo store sweep did not run")
    shared = fresh.get("shared_cache") or {}
    if shared.get("hits", 0) < shared_min_hits:
        problems.append(
            f"shared-store cross-worker hits {shared.get('hits', 0)} < "
            f"{shared_min_hits}: sweep workers no longer reuse each "
            f"other's solves")
    # absolute floor, not baseline-relative: how much of the key overlap
    # lands cross-worker depends on pool scheduling (which worker starts
    # first), so the rate is noisy — the floor certifies genuine reuse
    # without gating on scheduler luck
    fresh_shr = _shared_hit_rate(fresh)
    if fresh_shr < shared_min_rate:
        problems.append(
            f"shared-store hit-rate {fresh_shr:.4f} < floor "
            f"{shared_min_rate:g} (baseline {_shared_hit_rate(base):.4f})")
    # the candidate-pruning row: the pruned argmin must select identical
    # winners while pricing STRICTLY fewer candidate rows, at no
    # throughput loss beyond scheduler noise
    prune = fresh.get("prune")
    if not prune:
        problems.append("prune block missing: the candidate-pruning sweep "
                        "did not run")
    else:
        if not prune.get("enabled", False):
            problems.append("prune.enabled is False: the pruning stage was "
                            "bypassed")
        if not prune.get("winners_identical", False):
            problems.append("prune.winners_identical is False: the pruned "
                            "argmin no longer reproduces the unpruned rows")
        enum_, priced = prune.get("enumerated", 0), prune.get("priced", 0)
        if not (0 < priced < enum_):
            problems.append(
                f"pruning priced {priced} of {enum_} enumerated candidate "
                f"rows; the gate requires 0 < priced < enumerated")
        on = prune.get("points_per_s_on", 0.0)
        off = prune.get("points_per_s_off", 0.0)
        if on < off / prune_slack:
            problems.append(
                f"prune-on throughput {on:.1f} points/s < prune-off "
                f"{off:.1f} / slack {prune_slack:g}")
    # the budgeted-search block: every shipped policy certified on the
    # smoke grid, the dense-grid halving run certified within its
    # evaluation-fraction cap
    search = fresh.get("search")
    if not search:
        problems.append("search block missing: the budgeted-search "
                        "benchmark did not run")
    else:
        base_search = base.get("search") or {}
        base_pols = (base_search.get("smoke") or {}).get("policies", {})
        fresh_pols = (search.get("smoke") or {}).get("policies", {})
        for pol in base_pols:
            if pol not in fresh_pols:
                problems.append(f"search policy {pol!r} missing from the "
                                f"fresh report")
        for pol, entry in fresh_pols.items():
            _check_search_entry(problems, f"search:{pol}", entry,
                                base_pols.get(pol, {}), slowdown)
        dense = search.get("dense")
        if not dense:
            problems.append("search.dense missing: the dense-grid "
                            "budgeted search did not run")
        else:
            _check_search_entry(problems, "search:dense", dense,
                                base_search.get("dense", {}), slowdown)
            frac = dense.get("eval_frac", 1.0)
            if frac > search_max_frac:
                problems.append(
                    f"search:dense spent {frac:.3f} of exhaustive "
                    f"evaluations > cap {search_max_frac:g}")
    # the compiled f32 drift-budget contract block
    _check_compiled(problems, fresh, base, slowdown, grid_min_cells,
                    repriced_max_frac)
    # the warm-daemon service block
    _check_service(problems, fresh, base, slowdown, service_min_speedup,
                   service_min_dedup)
    # the learned rank-stage block
    _check_learned(problems, fresh, rank_shrink)
    return problems


def _tier1_failure(timeout_s: float = 1800.0) -> str | None:
    """Run the tier-1 suite; None when green, else a short description.

    Guards `--update`: a bench baseline captured while tests are red
    would bless a broken tree as the new normal."""
    import subprocess

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(REPO / "src"), env.get("PYTHONPATH")) if p)
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "pytest", "-x", "-q"], cwd=REPO, env=env,
            capture_output=True, text=True, timeout=timeout_s)
    except subprocess.TimeoutExpired:
        return f"tier-1 suite timed out after {timeout_s:g}s"
    if proc.returncode == 0:
        return None
    tail = "\n".join((proc.stdout + proc.stderr).strip().splitlines()[-15:])
    return f"tier-1 suite exited {proc.returncode}:\n{tail}"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", type=pathlib.Path, default=BASELINE,
                    help=f"baseline JSON (default {BASELINE})")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline with fresh numbers")
    ap.add_argument("--fresh-out", type=pathlib.Path,
                    default=os.environ.get("DFMODEL_BENCH_FRESH_OUT") or None,
                    help="also keep the fresh report at this path (CI "
                         "uploads it as an artifact on failure)")
    args = ap.parse_args()

    slowdown = float(os.environ.get("DFMODEL_BENCH_SLOWDOWN", "4.0"))
    min_speedup = float(os.environ.get("DFMODEL_BENCH_MIN_SPEEDUP", "0.8"))
    hit_drop = float(os.environ.get("DFMODEL_BENCH_HIT_DROP", "0.02"))
    shared_min_hits = int(os.environ.get("DFMODEL_BENCH_SHARED_MIN_HITS",
                                         "1"))
    shared_min_rate = float(os.environ.get("DFMODEL_BENCH_SHARED_MIN_RATE",
                                           "0.002"))
    prune_slack = float(os.environ.get("DFMODEL_BENCH_PRUNE_SLACK", "1.5"))
    search_max_frac = float(os.environ.get("DFMODEL_BENCH_SEARCH_MAX_FRAC",
                                           "0.2"))
    grid_min_cells = int(os.environ.get("DFMODEL_BENCH_GRID_MIN_CELLS",
                                        "100000"))
    repriced_max_frac = float(os.environ.get("DFMODEL_BENCH_REPRICED_FRAC",
                                             "0.5"))
    service_min_speedup = float(os.environ.get(
        "DFMODEL_BENCH_SERVICE_MIN_SPEEDUP", "2.0"))
    service_min_dedup = int(os.environ.get(
        "DFMODEL_BENCH_SERVICE_MIN_DEDUP", "1"))
    rank_shrink = float(os.environ.get("DFMODEL_BENCH_RANK_SHRINK", "3.0"))

    if args.update:
        print("bench gate: --update requested; running the tier-1 suite "
              "first (a red tree must not become the baseline)")
        failure = _tier1_failure()
        if failure is not None:
            print(f"bench gate: REFUSING --update, {failure}",
                  file=sys.stderr)
            return 1
    fresh = _fresh_report(args.fresh_out)
    if args.update:
        args.baseline.write_text(json.dumps(fresh, indent=2) + "\n")
        print(f"bench baseline updated: {args.baseline} "
              f"(warm phased speedup "
              f"{fresh['speedup_phased_vs_perpoint']:.2f}x)")
        return 0
    if not args.baseline.exists():
        print(f"bench gate: no baseline at {args.baseline}; "
              f"run with --update to create one", file=sys.stderr)
        return 1
    base = json.loads(args.baseline.read_text())
    problems = compare(fresh, base, slowdown, min_speedup, hit_drop,
                       shared_min_hits=shared_min_hits,
                       shared_min_rate=shared_min_rate,
                       prune_slack=prune_slack,
                       search_max_frac=search_max_frac,
                       grid_min_cells=grid_min_cells,
                       repriced_max_frac=repriced_max_frac,
                       service_min_speedup=service_min_speedup,
                       service_min_dedup=service_min_dedup,
                       rank_shrink=rank_shrink)
    for path, vals in fresh.get("paths", {}).items():
        print(f"  {path:20s} {vals['points_per_s']:10.1f} points/s "
              f"(baseline "
              f"{base.get('paths', {}).get(path, {}).get('points_per_s', 0.0):10.1f})")
    shared = fresh.get("shared_cache") or {}
    print(f"  shared store [{shared.get('backend', '-')}]: "
          f"{shared.get('hits', 0)} cross-worker hits, "
          f"{shared.get('entries', 0)} entries, hit rate "
          f"{_shared_hit_rate(fresh):.3f}")
    prune = fresh.get("prune") or {}
    print(f"  prune: {prune.get('enumerated', 0)} enumerated -> "
          f"{prune.get('priced', 0)} priced "
          f"({prune.get('shrink', 1.0):.2f}x rows), winners identical: "
          f"{prune.get('winners_identical', False)}")
    search = fresh.get("search") or {}
    for pol, entry in (search.get("smoke") or {}).get("policies",
                                                      {}).items():
        print(f"  search:{pol:10s} {entry.get('evals_used', 0):4d}/"
              f"{entry.get('grid_points', 0)} evals, certified: "
              f"{entry.get('winner_identical', False)}")
    dense = search.get("dense") or {}
    print(f"  search:dense      {dense.get('evals_used', 0):4d}/"
          f"{dense.get('grid_points', 0)} evals "
          f"(frac {dense.get('eval_frac', 1.0):.3f}), certified: "
          f"{dense.get('winner_identical', False)}")
    compiled = fresh.get("compiled") or {}
    if compiled.get("available"):
        cgrid = compiled.get("grid") or {}
        cstream = compiled.get("stream") or {}
        print(f"  compiled: {len(compiled.get('smoke') or {})} smoke "
              f"scenarios + {cgrid.get('cells', 0)} grid cells, winners "
              f"identical: {compiled.get('winners_identical', False)}, "
              f"repriced frac {cgrid.get('repriced_frac', 0.0):.3f}, "
              f"{cgrid.get('cells_per_s', 0.0):.0f} cells/s, stream "
              f"{cstream.get('rows_per_s', 0.0):.0f} rows/s")
    else:
        print("  compiled: unavailable (no jax)")
    service = fresh.get("service") or {}
    print(f"  service: warm {service.get('warm_request_s', 0.0):.3f}s vs "
          f"cold {service.get('cold_request_s', 0.0):.3f}s "
          f"({service.get('warm_speedup', 0.0):.1f}x), "
          f"{service.get('dedup_hits', 0)} cross-client dedup hits, "
          f"{service.get('rows_per_s', 0.0):.0f} warm rows/s, winners "
          f"identical: {service.get('winners_identical', False)}")
    learned = fresh.get("learned") or {}
    if learned.get("enabled"):
        lmodel = learned.get("model") or {}
        print(f"  learned: keep_frac {lmodel.get('keep_frac', 0.0):.3f}, "
              f"recall {lmodel.get('recall', 0.0):.3f} (target "
              f"{lmodel.get('recall_target', 0.0):g}), dense-grid shrink "
              f"{learned.get('shrink_vs_dominance', 0.0):.2f}x, winners "
              f"identical: {learned.get('winners_identical', False)}")
    else:
        print("  learned: disabled (no trainable harvest)")
    if problems:
        print("bench gate: REGRESSION", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        if args.fresh_out is not None:
            print(f"bench gate: fresh report kept at {args.fresh_out}",
                  file=sys.stderr)
        return 1
    print(f"bench gate: PASS (rows identical, warm phased speedup "
          f"{fresh['speedup_phased_vs_perpoint']:.2f}x, hit rate "
          f"{_hit_rate(fresh):.3f}, shared hits {shared.get('hits', 0)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""CI gate: the phased smoke sweep must reproduce the scalar reference
bit-for-bit on the pricing backend named by $DFMODEL_PRICING_BACKEND
(jax skips gracefully when the container lacks it).

  PYTHONPATH=src DFMODEL_PRICING_BACKEND=jax python tools/check_pricing_backend.py
"""
import os
import sys

backend = os.environ.get("DFMODEL_PRICING_BACKEND", "numpy")
if backend == "jax":
    try:
        import jax  # noqa: F401
    except Exception:
        print("pricing backend jax: SKIPPED (jax not installed)")
        sys.exit(0)

from repro.core import DSEEngine, clear_caches  # noqa: E402
from repro.core.dse import sweep  # noqa: E402
from repro.workloads.scenarios import get_scenario  # noqa: E402


def main() -> None:
    sc = get_scenario("llm", smoke=True)
    s = sc.spec
    clear_caches()
    ref = sweep(sc.work_fn, n_chips=s.n_chips, chips=s.chips,
                topologies=s.topologies, mem_net=s.mem_net, max_tp=s.max_tp,
                phased=False)
    pts = DSEEngine(parallel=False).sweep(sc.work_fn, s)  # backend from env
    assert [p.row() for p in pts] == [p.row() for p in ref], \
        f"pricing backend {backend} diverged from the scalar reference"
    print(f"pricing backend {backend}: {len(pts)} points, rows identical OK")


if __name__ == "__main__":
    main()

"""CI gate: the phased smoke sweep must reproduce the scalar reference
bit-for-bit on the pricing backend named by $DFMODEL_PRICING_BACKEND
(jax / pallas / pallas-compiled skip gracefully when the container
lacks jax).

  PYTHONPATH=src DFMODEL_PRICING_BACKEND=jax python tools/check_pricing_backend.py
  PYTHONPATH=src DFMODEL_PRICING_BACKEND=pallas python tools/check_pricing_backend.py
  PYTHONPATH=src DFMODEL_PRICING_BACKEND=pallas-compiled python tools/check_pricing_backend.py

For the pallas backend the kernel package's own certification harness
(`repro.kernels.pricing.certify` — row-identity of the interpret-mode
kernel against the float64 scalar reference) runs first, then the same
end-to-end sweep comparison the other backends get. For pallas-compiled
the f32 twin (`certify_f32` — outputs within the declared drift band of
the f64 reference) runs instead; the end-to-end sweep then proves the
drift-budget contract: banded f32 selection + exact f64 re-pricing
reproduces the scalar winners bit-for-bit.
"""
import os
import sys

backend = os.environ.get("DFMODEL_PRICING_BACKEND", "numpy")
if backend in ("jax", "pallas", "pallas-compiled"):
    try:
        import jax  # noqa: F401
    except Exception:
        print(f"pricing backend {backend}: SKIPPED (jax not installed)")
        sys.exit(0)

from repro.core import DSEEngine, clear_caches  # noqa: E402
from repro.core.dse import sweep  # noqa: E402
from repro.workloads.scenarios import get_scenario  # noqa: E402


def main() -> None:
    if backend == "pallas":
        from repro.kernels.pricing import certify

        report = certify(n=512, seed=0)
        print(f"pallas pricing kernel certification: {report}")
    elif backend == "pallas-compiled":
        from repro.kernels.pricing import certify_f32

        report = certify_f32(n=512, seed=0)
        print(f"compiled f32 pricing kernel certification: {report}")
    sc = get_scenario("llm", smoke=True)
    s = sc.spec
    clear_caches()
    ref = sweep(sc.work_fn, n_chips=s.n_chips, chips=s.chips,
                topologies=s.topologies, mem_net=s.mem_net, max_tp=s.max_tp,
                phased=False)
    engine = DSEEngine(parallel=False)  # backend from env, pruning default-on
    pts = engine.sweep(sc.work_fn, s)
    assert [p.row() for p in pts] == [p.row() for p in ref], \
        f"pricing backend {backend} diverged from the scalar reference"
    st = engine.last_plan_stats or {}
    print(f"pricing backend {backend}: {len(pts)} points, rows identical OK "
          f"(pruned {st.get('enumerated', 0)} -> {st.get('priced', 0)} "
          f"candidate rows)")
    drift = engine.last_drift_stats
    if drift is not None:
        print(f"drift contract: band {drift['band']:g}, "
              f"{drift['repriced']}/{drift['rows']} rows exactly re-priced, "
              f"max iter drift {drift['max_iter_drift']:.3g}, "
              f"max mem drift {drift['max_mem_drift']:.3g}")


if __name__ == "__main__":
    main()

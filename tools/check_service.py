"""CI gate: the DSE service daemon's multi-client contract.

Starts one in-process ``DSEService`` daemon, runs TWO concurrent
clients sweeping overlapping two-thirds grids of the smoke llm
scenario, and asserts the house invariants end-to-end over the real
unix-socket transport:

* every row each client receives is bit-identical to a direct
  ``DSEEngine.sweep`` over the same cells (so the winners are too);
* the shared cells are priced exactly once by the daemon
  (``cells_priced`` equals the union of both grids) with cross-client
  dedup hits > 0;
* a warm full-grid repeat streams entirely from the shared memo (zero
  new prices) and also matches the direct sweep bit-for-bit;
* a malformed request gets a structured error and the daemon keeps
  serving on the same connection.

  PYTHONPATH=src python tools/check_service.py
"""
import sys
import threading

from repro.core import DSEEngine
from repro.service import DSEClient, DSEService, ServiceError
from repro.workloads.scenarios import get_scenario

SCENARIO = "llm"


def main() -> int:
    sc = get_scenario(SCENARIO, smoke=True)
    eng = DSEEngine(parallel=False)
    ref = {it.index: it.point
           for it in eng.sweep_cells_iter(sc.work_fn, sc.spec.grid(),
                                          sc.spec)}
    direct_rows = [p.row() for p in ref.values() if p is not None]
    n = len(sc.spec.grid())
    grids = {"A": list(range(0, 2 * n // 3)),
             "B": list(range(n // 3, n))}
    overlap = set(grids["A"]) & set(grids["B"])
    replies: dict = {}

    with DSEService(batch_cells=4) as svc:
        def run(name):
            with DSEClient(svc.path) as cli:
                replies[name] = cli.sweep(scenario=SCENARIO, smoke=True,
                                          cells=grids[name], client=name)

        threads = [threading.Thread(target=run, args=(name,))
                   for name in grids]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)

        with DSEClient(svc.path) as cli:
            sched = cli.stats()["scheduler"]
            warm = cli.sweep(scenario=SCENARIO, smoke=True)
            warm_priced = cli.stats()["scheduler"]["cells_priced"]
            try:
                cli.sweep(scenario="no-such-scenario")
            except ServiceError as exc:
                assert exc.code == "unknown-scenario", exc.code
            else:
                raise AssertionError("malformed request did not error")
            assert cli.ping()["kind"] == "pong", "daemon died after error"

    assert set(replies) == set(grids), f"clients finished: {set(replies)}"
    for name, cells in grids.items():
        rep = replies[name]
        assert sorted(rep.indices) == cells, f"client {name} row coverage"
        for idx, pt in zip(rep.indices, rep.points):
            want = ref[idx]
            assert (pt is None) == (want is None), f"cell {idx} feasibility"
            if pt is not None:
                assert pt.row() == want.row(), f"cell {idx} row drift"
        print(f"client {name}: {rep.summary['rows']} rows, winner cell "
              f"{rep.summary['winner']['index']}, "
              f"{rep.summary['dedup_hits']} dedup hits -> identical to "
              f"direct sweep")
    assert sched["cells_priced"] == n, (
        f"priced {sched['cells_priced']} cells, expected exactly {n}")
    assert sched["dedup_hits"] >= len(overlap) > 0, (
        f"cross-client dedup hits {sched['dedup_hits']} < overlap "
        f"{len(overlap)}")
    assert warm_priced == n, "warm repeat priced new cells"
    assert warm.rows() == direct_rows, "warm sweep rows drifted"
    print(f"daemon: {sched['cells_priced']} cells priced once for "
          f"{sched['rows_streamed']} streamed rows "
          f"({sched['dedup_hits']} cross-client dedup hits); warm repeat "
          f"from memo, bit-identical")
    print("service smoke: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""CI gate for the modeled-vs-measured validation loop.

For every smoke serving scenario with an executable twin (`serving`,
`mamba2`, `moe`) this gate compares the analytical prediction against the
twin's execution and applies the declared error bands of
`repro.validation.report`:

* **dry-run channel (mandatory)** — FLOPs / DRAM bytes / collective link
  bytes of one decode step, counted from the twin's compiled HLO by
  `repro.launch.hlocost`. With jax importable the HLO is lowered fresh on
  this machine; without jax the gate falls back to the *measured* numbers
  committed in `BENCH_validation.json` and still re-derives the analytical
  predictions from scratch — so a model-side drift fails CI even on an
  interpreter that cannot run XLA.
* **wall-clock channel** — steady-state TPOT on a real `ServeEngine`
  (warmup discarded, per-step sync, trimmed mean), gated one-sided on the
  compute term everywhere and two-sided through the hybrid roofline on
  `wall_gate` cases. Requires jax; skipped with a visible notice
  otherwise (CI wall clocks are noise, the committed baseline records the
  owning machine's numbers).

Exit 1 on any band violation. `--update` re-measures everything on this
machine (jax required) and rewrites `BENCH_validation.json`.

  PYTHONPATH=src python tools/check_validation.py [--update]
                                                  [--baseline PATH]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
BASELINE = REPO / "BENCH_validation.json"


def _fresh_rows(update: bool) -> tuple[list[dict], dict | None]:
    """Measure every case on this machine (jax required). Returns
    (case rows, calibration dict)."""
    from repro.validation import (build_case, build_case_report,
                                  calibrate_host, measure_dryrun,
                                  measure_wallclock, predict_case)

    cal = calibrate_host()
    calibration = {"flop_rate": cal.flop_rate, "mem_bw": cal.mem_bw}
    print(f"  host calibration: {cal.flop_rate / 1e9:.1f} GFLOP/s matmul, "
          f"{cal.mem_bw / 1e9:.2f} GB/s stream")
    rows = []
    from repro.validation import CASE_NAMES
    for name in CASE_NAMES:
        case = build_case(name)        # certifies twin correspondence
        predicted = predict_case(case, cal.flop_rate, cal.mem_bw)
        dry = measure_dryrun(case)
        wall = measure_wallclock(case)
        rows.append(build_case_report(name, predicted, dry, wall,
                                      calibration, case.twin.wall_gate))
    return rows, calibration


def _baseline_rows(base: dict) -> list[dict]:
    """Re-derive predictions fresh (numpy-only), reuse the committed
    measured numbers; drop wall-clock sections (another machine's clock
    means nothing here — dry-run counts are machine-independent)."""
    from repro.validation import build_case, build_case_report, predict_case

    by_name = {row["case"]: row for row in base["cases"]}
    rows = []
    for name, brow in by_name.items():
        case = build_case(name)
        cal = base.get("calibration") or {}
        predicted = predict_case(case, cal.get("flop_rate", 1e11),
                                 cal.get("mem_bw", 4e9))
        rows.append(build_case_report(name, predicted, brow["dryrun"],
                                      None, None, case.twin.wall_gate))
    return rows


def _print_rows(rows: list[dict]) -> None:
    for row in rows:
        r = row["ratios"]
        line = (f"  {row['case']:10s} flops x{r['flops']:.4f}  "
                f"bytes x{r['bytes']:.2f}  collective Δ "
                f"{row['collective_delta_bytes']:.0f} B")
        if "wallclock" in row:
            line += (f"  | TPOT {row['wallclock']['tpot'] * 1e3:.1f} ms, "
                     f"compute-term x{r['compute_term']:.3f}, "
                     f"hybrid x{r['hybrid']:.3f}"
                     f"{' [gated]' if row['wall_gate'] else ''}")
        print(line)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", type=pathlib.Path, default=BASELINE,
                    help=f"baseline JSON (default {BASELINE})")
    ap.add_argument("--update", action="store_true",
                    help="re-measure on this machine and rewrite the "
                         "baseline (jax required)")
    args = ap.parse_args()

    from repro.validation import (check_report, have_jax, validation_band,
                                  bytes_factor, wall_band)

    jax_ok = have_jax()
    if args.update and not jax_ok:
        print("validation gate: --update needs jax to measure; none "
              "importable here", file=sys.stderr)
        return 1

    if jax_ok:
        rows, calibration = _fresh_rows(args.update)
        report = {
            "bands": {"band": validation_band(),
                      "bytes_factor": bytes_factor(),
                      "wall_band": wall_band()},
            "calibration": calibration,
            "cases": rows,
        }
        if args.update:
            args.baseline.write_text(
                json.dumps(report, indent=2, sort_keys=True) + "\n")
            _print_rows(rows)
            print(f"validation baseline updated: {args.baseline}")
            return 0
    else:
        print("validation gate: jax not importable — wall-clock channel "
              "SKIPPED; gating fresh analytical predictions against the "
              "committed measured dry-run counts")
        if not args.baseline.exists():
            print(f"validation gate: no baseline at {args.baseline}; run "
                  f"--update on a jax machine first", file=sys.stderr)
            return 1
        base = json.loads(args.baseline.read_text())
        report = {"cases": _baseline_rows(base)}

    _print_rows(report["cases"])
    problems = check_report(report)
    if problems:
        print("validation gate: FAIL", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    n_wall = sum(1 for r in report["cases"] if "wallclock" in r)
    print(f"validation gate: PASS ({len(report['cases'])} cases dry-run "
          f"validated, {n_wall} wall-clock"
          f"{'' if jax_ok else ' [wall clocks skipped: no jax]'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env bash
# CI gate — mirrors .github/workflows/ci.yml so it can run locally too.
#
#   tools/ci.sh            # install dev deps, run tests + smoke benches
#   tools/ci.sh --no-install   # offline container: skip pip, tests still
#                              # collect (hypothesis tests skip themselves)
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" != "--no-install" ]]; then
    python -m pip install -r requirements-dev.txt \
        || echo "WARN: pip install failed (offline?); property tests will skip"
fi

# the seed regression this gate exists for: collection must never fail,
# with or without the dev extras installed
PYTHONPATH=src python -m pytest -x -q

# smoke benches: exercises the DSE engine end-to-end (parallel sweep,
# memo cache, Pareto frontier, serial-vs-engine row identity)
PYTHONPATH=src python -m benchmarks.run --smoke

# pricing backends: the phased smoke sweep must reproduce the scalar
# reference bit-for-bit on BOTH batched backends (jax skips gracefully if
# the container lacks it)
for backend in numpy jax; do
    PYTHONPATH=src DFMODEL_PRICING_BACKEND=$backend \
        python tools/check_pricing_backend.py
done

#!/usr/bin/env bash
# CI gate — mirrors .github/workflows/ci.yml so it can run locally too.
#
#   tools/ci.sh            # install dev deps, run tests + smoke benches
#   tools/ci.sh --no-install   # offline container: skip pip, tests still
#                              # collect (hypothesis tests skip themselves)
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" != "--no-install" ]]; then
    python -m pip install -r requirements-dev.txt \
        || echo "WARN: pip install failed (offline?); property tests will skip"
fi

# the seed regression this gate exists for: collection must never fail,
# with or without the dev extras installed
PYTHONPATH=src python -m pytest -x -q

# smoke benches: exercises the DSE engine end-to-end (parallel sweep,
# memo cache, Pareto frontier, serial-vs-engine row identity)
PYTHONPATH=src python -m benchmarks.run --smoke

# pricing backends: the phased smoke sweep must reproduce the scalar
# reference bit-for-bit on every batched backend. The jax and pallas legs
# need jax; skip them HERE with an explicit line (rather than relying on
# the checker's internal skip) so offline-container logs are unambiguous.
if python -c "import jax" >/dev/null 2>&1; then HAVE_JAX=1; else HAVE_JAX=0; fi
for backend in numpy jax pallas; do
    if [[ "$backend" != numpy && "$HAVE_JAX" == 0 ]]; then
        echo "pricing backend $backend: SKIP (no jax)"
        continue
    fi
    PYTHONPATH=src DFMODEL_PRICING_BACKEND=$backend \
        python tools/check_pricing_backend.py
done

# bench-regression gate: fresh smoke BENCH_dse.json vs the committed
# baseline (row identity, points/sec floor, warm phased speedup, memo
# cache hit-rate) — see tools/check_bench.py for the tolerances
PYTHONPATH=src python tools/check_bench.py

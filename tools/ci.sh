#!/usr/bin/env bash
# CI gate — mirrors .github/workflows/ci.yml so it can run locally too.
#
#   tools/ci.sh            # install dev deps, run tests + smoke benches
#   tools/ci.sh --no-install   # offline container: skip pip, tests still
#                              # collect (hypothesis tests skip themselves)
#
# Every gate ends with an explicit "<gate>: PASS" (or ": SKIP (reason)")
# line so offline-container logs are unambiguous; the first failure stops
# the script with the failing gate named.
set -euo pipefail
cd "$(dirname "$0")/.."

gate() {  # gate <name> <cmd...> — run, then print "<name>: PASS"
    local name="$1"; shift
    if "$@"; then
        echo "$name: PASS"
    else
        echo "$name: FAIL" >&2
        exit 1
    fi
}

if [[ "${1:-}" != "--no-install" ]]; then
    python -m pip install -r requirements-dev.txt \
        || echo "WARN: pip install failed (offline?); property tests will skip"
fi

# the seed regression this gate exists for: collection must never fail,
# with or without the dev extras installed
gate "tests" env PYTHONPATH=src python -m pytest -x -q

# engine matrix: the DSEEngine + cross-process shared memo store under
# every pool transport this platform offers, plus a candidate-pruning
# OFF leg and a learned-rank ON leg. This local mirror runs the store-ON
# legs (prune on, rank off) plus one prune-off and one rank-on leg only —
# the "tests" gate above already ran the full suite in the default
# configuration (fork transport, store off, prune on, rank off), and
# these legs run serially here; the workflow's engine-matrix job fans
# the full transport × store × prune × rank grid out across parallel
# runners.
for method in fork spawn forkserver; do
    if ! python -c "import multiprocessing as m, sys; \
sys.exit(0 if '$method' in m.get_all_start_methods() else 1)"; then
        echo "engine matrix [$method shared=1 prune=1 rank=0]: SKIP (start method unavailable)"
        continue
    fi
    gate "engine matrix [$method shared=1 prune=1 rank=0]" \
        env PYTHONPATH=src DFMODEL_TEST_MP_CONTEXT=$method \
            DFMODEL_TEST_SHARED_CACHE=1 DFMODEL_TEST_PRUNE=1 \
            DFMODEL_TEST_RANK=0 \
            python -m pytest -x -q tests/test_memo_store.py \
                tests/test_dse_engine.py tests/test_learned.py
done
if python -c "import multiprocessing as m, sys; \
sys.exit(0 if 'fork' in m.get_all_start_methods() else 1)"; then
    # DFMODEL_TEST_PRUNE=0 reshapes _engine-built engines; DFMODEL_PRUNE=off
    # flips every prune="auto" default (sweep, plan_design_groups) too
    gate "engine matrix [fork shared=1 prune=0 rank=0]" \
        env PYTHONPATH=src DFMODEL_TEST_MP_CONTEXT=fork \
            DFMODEL_TEST_SHARED_CACHE=1 DFMODEL_TEST_PRUNE=0 \
            DFMODEL_TEST_RANK=0 DFMODEL_PRUNE=off \
            python -m pytest -x -q tests/test_memo_store.py \
                tests/test_dse_engine.py tests/test_learned.py
    # DFMODEL_TEST_RANK=1 reshapes _engine-built engines; DFMODEL_RANK=on
    # flips every rank="auto" default too. Correctness must not depend on
    # the harvest: cold engines degrade to rank-off, warm engines rank
    # and still certify identical winners.
    gate "engine matrix [fork shared=1 prune=1 rank=1]" \
        env PYTHONPATH=src DFMODEL_TEST_MP_CONTEXT=fork \
            DFMODEL_TEST_SHARED_CACHE=1 DFMODEL_TEST_PRUNE=1 \
            DFMODEL_TEST_RANK=1 DFMODEL_RANK=on \
            python -m pytest -x -q tests/test_memo_store.py \
                tests/test_dse_engine.py tests/test_learned.py
else
    echo "engine matrix [fork shared=1 prune=0 rank=0]: SKIP (start method unavailable)"
    echo "engine matrix [fork shared=1 prune=1 rank=1]: SKIP (start method unavailable)"
fi

# smoke benches: exercises the DSE engine end-to-end (parallel sweep,
# memo cache + shared store, Pareto frontier, serial-vs-engine row
# identity). `benchmarks` is a real package, so `-m benchmarks.run`
# resolves from the repo root — the same layout check_bench.py imports.
gate "smoke benchmarks" env PYTHONPATH=src python -m benchmarks.run --smoke

# pricing backends: the phased smoke sweep must reproduce the scalar
# reference bit-for-bit on every batched backend — including the
# approximate pallas-compiled f32 backend, whose drift-budget contract
# (banded selection + exact f64 re-pricing) makes bit-identity hold
# there too. The jax-family legs need jax; skip them HERE with an
# explicit line (rather than relying on the checker's internal skip) so
# offline-container logs are unambiguous.
if python -c "import jax" >/dev/null 2>&1; then HAVE_JAX=1; else HAVE_JAX=0; fi
for backend in numpy jax pallas pallas-compiled; do
    if [[ "$backend" != numpy && "$HAVE_JAX" == 0 ]]; then
        echo "pricing backend $backend: SKIP (no jax)"
        continue
    fi
    gate "pricing backend $backend" \
        env PYTHONPATH=src DFMODEL_PRICING_BACKEND=$backend \
            python tools/check_pricing_backend.py
done

# search certification: every budgeted policy must recover the
# exhaustive argmin on every smoke scenario (the search tests raise on
# a miss), under both pool regimes the start-method auto-pick chooses
# between — fork (jax never imported) and forkserver (jax loaded).
for method in fork forkserver; do
    if ! python -c "import multiprocessing as m, sys; \
sys.exit(0 if '$method' in m.get_all_start_methods() else 1)"; then
        echo "search certification [$method]: SKIP (start method unavailable)"
        continue
    fi
    gate "search certification [$method]" \
        env PYTHONPATH=src DFMODEL_TEST_MP_CONTEXT=$method \
            python -m pytest -x -q tests/test_search.py
done

# DSE service: one warm daemon, two concurrent clients sweeping
# overlapping grids over the real unix-socket transport — winners
# bit-identical to a direct DSEEngine.sweep, shared cells priced
# exactly once (cross-client dedup), warm repeat served from the memo,
# malformed requests answered structurally with the daemon surviving
gate "service smoke" env PYTHONPATH=src python tools/check_service.py

# docs freshness: every repro.* module ARCHITECTURE.md names must
# import, the ENV_VARS.md catalogue must match the DFMODEL_* knobs the
# tree actually reads, and the doctest transcripts must execute
gate "docs freshness" \
    env PYTHONPATH=src python -m pytest -x -q tests/test_docs.py

# bench-regression gate: fresh smoke BENCH_dse.json vs the committed
# baseline (row identity, points/sec floors, warm phased speedup, memo
# cache hit-rate, shared-store cross-worker hits) — tolerances in
# tools/check_bench.py
gate "bench regression" env PYTHONPATH=src python tools/check_bench.py

# modeled-vs-measured validation: every smoke serving scenario's
# analytical prediction gated against its executable twin's dry-run HLO
# counts (mandatory — with jax the HLO is lowered fresh, without jax the
# fresh predictions gate against the committed measured counts) and, on
# jax machines, its steady-state wall clock under the hybrid-roofline
# band — baseline BENCH_validation.json, bands in repro.validation.report
gate "validation" env PYTHONPATH=src python tools/check_validation.py

echo "ci.sh: all gates passed"
